//! The request queue, dynamic batcher, and worker pool.
//!
//! Single-sample requests enter a FIFO queue; free workers take up to
//! `max_batch` queued requests at once, round the count **up** to the
//! smallest batch bucket that fits (padding with zero samples), execute
//! the bucket's forward plan, and surface only the real rows — the padded
//! rows are masked out and never leave the worker. Bucketing keeps the
//! number of distinct execution plans logarithmic in the maximum batch
//! while a growing backlog automatically rides up the bucket ladder
//! (deeper queue → bigger batches → higher throughput, the classic
//! dynamic-batching trade against per-request latency).
//!
//! Sequence models add a second grouping axis: requests are queued per
//! **length bucket** (the smallest power-of-two step count that fits the
//! request, up to the arch's capacity `T`), and a worker dispatches from
//! exactly one length bucket at a time — the bucket whose front request
//! has waited longest, so no length is starved. A co-batched group is
//! zero-padded in time to its length bucket and executed as a prefix run
//! of the batch bucket's plan ([`InferenceModel::forward_seq_with`]);
//! short requests never pay for the arch's full unroll, which is where
//! the padded-vs-bucketed useful-words/s gap in the `serve_load` bench
//! comes from.
//!
//! Shutdown is drain-first: [`Server::shutdown`] stops intake, wakes the
//! workers, and joins them only after the queue is empty — every accepted
//! request gets exactly one response (asserted by the drain test).

use crate::modelio::ModelArtifact;
use crate::serve::metrics::{ServeReport, ServeStats, ServerInfo};
use crate::serve::slo::{classify, SloOutcome, SloSpec};
use crate::telemetry::health::{self, Health, HeartbeatGroup};
use crate::serve::model::{InferenceModel, ServeScratch};
use crate::telemetry::trace::{self, SpanEvent, SpanKind, TraceGroup};
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Worker-pool shape. `workers` is the number of serving threads pulling
/// batches; each executes its plan with the thread count the model was
/// built with (worker-level parallelism and primitive-level parallelism
/// compose).
#[derive(Debug, Clone, Copy)]
pub struct ServeOpts {
    pub max_batch: usize,
    pub workers: usize,
    /// Batching delay knob: when a worker would dispatch a partial batch,
    /// it may wait up to this many microseconds for the bucket to fill
    /// (new arrivals wake it immediately; a full `max_batch`, shutdown,
    /// or the deadline dispatch whatever is queued). `0` — the default —
    /// preserves greedy dispatch: take whatever is queued, immediately.
    /// The trade is the classic one: a small window raises batch fill
    /// (throughput) at the cost of adding up to the window to latency.
    pub wait_for_fill_us: u64,
    /// Record request/batch spans into the installed span tracer
    /// ([`crate::telemetry::trace`]). Opt-in per server so a server that
    /// did not ask for tracing never writes into a tracer some *other*
    /// component installed (the CLI sets it alongside `--trace-out` /
    /// `--admin-sock`). No tracer installed ⇒ no spans either way.
    pub trace: bool,
    /// Latency SLO: when set, every request is stamped with a deadline
    /// at submit (this spec's default, per-request override allowed) and
    /// classified met/violated on respond, with violations attributed to
    /// their dominant stage ([`crate::serve::slo`]). `None` — the
    /// default — keeps the whole SLO plane to one branch per batch.
    pub slo: Option<SloSpec>,
    /// Register this server's workers with the installed health monitor
    /// ([`crate::telemetry::health`]). Opt-in per server like `trace`, so
    /// a server that did not ask for monitoring never beats into a
    /// monitor some other component installed. No monitor installed ⇒ no
    /// heartbeats either way.
    pub health: bool,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            max_batch: 8,
            workers: 2,
            wait_for_fill_us: 0,
            trace: false,
            slo: None,
            health: false,
        }
    }
}

/// One completed request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Plain `[classes]` logits of this request's row.
    pub logits: Vec<f32>,
    /// Enqueue → response seconds.
    pub latency_secs: f64,
    /// The bucket size the request was co-batched into.
    pub bucket: usize,
    /// Real (non-padded) rows in that batch.
    pub fill: usize,
    /// The runtime sequence-length bucket the batch dispatched under
    /// (`0` for fixed-shape models).
    pub len_bucket: usize,
}

struct Pending {
    id: u64,
    input: Vec<f32>,
    /// True step count of a sequence request (`0` for fixed-shape).
    len: usize,
    enqueued: Instant,
    /// Absolute latency budget stamped at submit: the per-request
    /// override when given, else the server's [`SloSpec`] default, else
    /// `f64::INFINITY` (no SLO — every request trivially meets it).
    deadline_secs: f64,
}

struct QueueState {
    /// Per-length-bucket FIFO queues, keyed by the request's length
    /// bucket (fixed-shape models use the single key `0`). A dispatch
    /// drains from exactly one length bucket, so a batch never mixes
    /// runtime lengths beyond its own bucket's padding.
    queues: BTreeMap<usize, VecDeque<Pending>>,
    /// Total backlog across every length bucket.
    depth: usize,
    /// Requests dequeued into a batch but not yet responded to. A drain
    /// is complete only when `depth == 0 && in_flight == 0` — the queue
    /// being empty says nothing about batches still computing.
    in_flight: usize,
    accepting: bool,
    next_id: u64,
}

impl QueueState {
    fn push(&mut self, len_bucket: usize, p: Pending) {
        self.queues.entry(len_bucket).or_default().push_back(p);
        self.depth += 1;
    }

    /// The length bucket whose front request has waited longest — FIFO
    /// fairness across buckets (a deep backlog surfaces there anyway,
    /// since its front is its oldest).
    fn oldest_bucket(&self) -> Option<usize> {
        self.queues
            .iter()
            .filter_map(|(&lb, q)| q.front().map(|p| (p.enqueued, lb)))
            .min()
            .map(|(_, lb)| lb)
    }

    /// The deepest single length bucket (what a fill window can hope to
    /// dispatch in one batch).
    fn max_bucket_depth(&self) -> usize {
        self.queues.values().map(|q| q.len()).max().unwrap_or(0)
    }
}

/// Validate one request's shape and resolve `(true_len, len_bucket)`:
/// fixed-shape models demand exactly `input_dim` features (sentinel
/// `(0, 0)`); sequence models accept any flattened `[len][c]` sequence
/// with `1 <= len <= t`.
fn classify_request(model: &InferenceModel, input: &[f32]) -> (usize, usize) {
    match model.seq_step_dim() {
        None => {
            assert_eq!(input.len(), model.input_dim(), "request shape mismatch");
            (0, 0)
        }
        Some(c) => {
            let cap = model.seq_max_len().unwrap();
            assert!(
                !input.is_empty() && input.len() % c == 0 && input.len() / c <= cap,
                "request shape mismatch: {} floats is not 1..={} whole steps of {} features",
                input.len(),
                cap,
                c
            );
            let len = input.len() / c;
            (len, model.len_bucket_for(len))
        }
    }
}

struct Shared {
    model: InferenceModel,
    opts: ServeOpts,
    state: Mutex<QueueState>,
    cv: Condvar,
    stats: Mutex<ServeStats>,
    /// Health wiring, captured once at [`Server::start`] (the tracer
    /// pattern): the installed monitor plus this server's heartbeat
    /// group. `None` — monitoring off or not requested — keeps every
    /// health touch in the worker loop to one branch.
    hb: Option<(Arc<Health>, Arc<HeartbeatGroup>)>,
}

impl Shared {
    /// Resolve a request's deadline: explicit per-request override in
    /// milliseconds, else the server's SLO default, else unbounded.
    fn deadline_for(&self, deadline_ms: Option<f64>) -> f64 {
        deadline_ms
            .map(|ms| ms * 1e-3)
            .or_else(|| self.opts.slo.map(|s| s.deadline_secs()))
            .unwrap_or(f64::INFINITY)
    }

    /// Static server identity attached to every report: what is running,
    /// with how much parallelism, over which bucket ladder.
    fn info(&self) -> ServerInfo {
        ServerInfo {
            arch: self.model.spec().to_arch().describe(),
            workers: self.opts.workers,
            threads: self.model.nthreads(),
            max_batch: self.opts.max_batch,
            buckets: self.model.buckets().to_vec(),
            len_buckets: self.model.len_buckets().to_vec(),
        }
    }
}

/// The serving front end: owns the queue and the worker pool.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    started: Instant,
}

impl Server {
    /// Spin up `opts.workers` worker threads over `model`. Returns the
    /// server handle and the response channel; the channel disconnects
    /// once every worker has exited (i.e. after [`Server::shutdown`]
    /// drained the queue), so a collector can simply `recv` to exhaustion.
    pub fn start(model: InferenceModel, opts: ServeOpts) -> (Server, mpsc::Receiver<Response>) {
        assert!(opts.workers >= 1, "need at least one worker");
        assert_eq!(
            opts.max_batch,
            model.max_batch(),
            "worker max_batch must equal the model's bucket ladder top"
        );
        if let Some(spec) = opts.slo {
            spec.validate().expect("invalid SLO spec");
        }
        // Health wiring mirrors the tracer's opt-in gating: the server
        // registers a heartbeat group only when it asked for monitoring
        // AND a monitor is installed.
        let hb = if opts.health {
            health::current().map(|h| {
                let g = h.register("serve", opts.workers);
                (h, g)
            })
        } else {
            None
        };
        let shared = Arc::new(Shared {
            model,
            opts,
            state: Mutex::new(QueueState {
                queues: BTreeMap::new(),
                depth: 0,
                in_flight: 0,
                accepting: true,
                next_id: 0,
            }),
            cv: Condvar::new(),
            stats: Mutex::new(match opts.slo {
                Some(spec) => ServeStats::with_slo(spec),
                None => ServeStats::new(),
            }),
            hb,
        });
        let (tx, rx) = mpsc::channel();
        let workers = (0..opts.workers)
            .map(|widx| {
                let shared = Arc::clone(&shared);
                let tx = tx.clone();
                std::thread::spawn(move || worker_loop(&shared, widx, &tx))
            })
            .collect();
        // Workers hold the only senders: dropping `tx` here makes the
        // channel disconnect exactly when the pool exits.
        drop(tx);
        (Server { shared, workers, started: Instant::now() }, rx)
    }

    /// Enqueue one single-sample request; returns its id. Fixed-shape
    /// models take exactly `input_dim` features; sequence models take any
    /// flattened `[len][c]` sequence with `1 <= len <= t`, queued under
    /// its length bucket. Panics if called after [`Server::shutdown`]
    /// (the queue is no longer accepting). The id doubles as the
    /// request's trace id — minted sequentially here, so the tracer's
    /// 1-in-N sampling is deterministic for a fixed load schedule.
    pub fn submit(&self, input: Vec<f32>) -> u64 {
        self.try_submit(input).expect("submit after shutdown")
    }

    /// [`Server::submit`] that signals shutdown/drain instead of
    /// panicking: `None` means the queue stopped accepting (an admin
    /// `drain` raced the load generator) and the request was not queued.
    pub fn try_submit(&self, input: Vec<f32>) -> Option<u64> {
        self.try_submit_with_deadline(input, None)
    }

    /// [`Server::try_submit`] with a per-request latency budget in
    /// milliseconds overriding the server's SLO default. `None` falls
    /// back to the default (or no deadline when no SLO is configured).
    pub fn try_submit_with_deadline(
        &self,
        input: Vec<f32>,
        deadline_ms: Option<f64>,
    ) -> Option<u64> {
        let (len, len_bucket) = classify_request(&self.shared.model, &input);
        let deadline_secs = self.shared.deadline_for(deadline_ms);
        let id = {
            let mut st = self.shared.state.lock().unwrap();
            if !st.accepting {
                return None;
            }
            let id = st.next_id;
            st.next_id += 1;
            st.push(
                len_bucket,
                Pending { id, input, len, enqueued: Instant::now(), deadline_secs },
            );
            id
        };
        self.shared.cv.notify_one();
        Some(id)
    }

    /// Enqueue a burst atomically (one lock, one wake-all): no worker can
    /// observe a partially submitted burst, so the batcher sees its full
    /// depth at once. Returns the ids in submission order.
    pub fn submit_all(&self, inputs: impl IntoIterator<Item = Vec<f32>>) -> Vec<u64> {
        let ids = {
            let mut st = self.shared.state.lock().unwrap();
            assert!(st.accepting, "submit after shutdown");
            let now = Instant::now();
            let deadline_secs = self.shared.deadline_for(None);
            inputs
                .into_iter()
                .map(|input| {
                    let (len, len_bucket) = classify_request(&self.shared.model, &input);
                    let id = st.next_id;
                    st.next_id += 1;
                    st.push(len_bucket, Pending { id, input, len, enqueued: now, deadline_secs });
                    id
                })
                .collect()
        };
        self.shared.cv.notify_all();
        ids
    }

    /// Requests accepted so far.
    pub fn submitted(&self) -> u64 {
        self.shared.state.lock().unwrap().next_id
    }

    /// Current queue backlog (across every length bucket).
    pub fn queue_len(&self) -> usize {
        self.shared.state.lock().unwrap().depth
    }

    /// Whether the queue is still taking requests (false once shutdown
    /// or an admin `drain` stopped intake).
    pub fn accepting(&self) -> bool {
        self.shared.state.lock().unwrap().accepting
    }

    /// Hot weight reload: atomically swap the serving model's weights for
    /// the artifact's (same arch required). Batches in flight finish on
    /// the weights they started with; batches taken after this call use
    /// the new set. The swap count lands in the final report.
    pub fn reload(&self, artifact: &ModelArtifact) -> Result<()> {
        self.shared.model.reload(artifact)
    }

    /// A cloneable handle that can apply reloads from another thread (the
    /// model-file watcher). The handle outlives the [`Server`] value —
    /// [`Server::shutdown`] consumes the server while the watcher keeps
    /// running until stopped — and a reload applied after shutdown is a
    /// harmless swap on the final weight generation.
    pub fn reload_handle(&self) -> ReloadHandle {
        ReloadHandle { shared: Arc::clone(&self.shared) }
    }

    /// Control-plane access for the admin socket: live stats, push
    /// reloads, and a blocking drain. Like [`Server::reload_handle`],
    /// the handle outlives the [`Server`] value.
    pub fn admin_handle(&self) -> AdminHandle {
        AdminHandle { shared: Arc::clone(&self.shared), started: self.started }
    }

    /// Point-in-time report over everything served so far. The run keeps
    /// going — this is what the periodic `--metrics-every` emitter prints;
    /// throughput uses the wall clock since [`Server::start`].
    pub fn stats_snapshot(&self) -> ServeReport {
        let wall = self.started.elapsed().as_secs_f64();
        let reloads = self.shared.model.reload_count();
        let mut r = self.shared.stats.lock().unwrap().report(wall, reloads);
        r.info = Some(self.shared.info());
        r
    }

    /// Stop intake, drain the queue, join the workers, and report. Every
    /// request accepted before this call is answered before it returns.
    pub fn shutdown(self) -> ServeReport {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.accepting = false;
        }
        if let Some((h, _)) = &self.shared.hb {
            h.set_draining();
        }
        self.shared.cv.notify_all();
        for h in self.workers {
            h.join().expect("serve worker panicked");
        }
        let wall = self.started.elapsed().as_secs_f64();
        let reloads = self.shared.model.reload_count();
        let mut r = self.shared.stats.lock().unwrap().report(wall, reloads);
        r.info = Some(self.shared.info());
        r
    }
}

/// Reload access to a running (or drained) server, detached from the
/// [`Server`] value's lifetime — see [`Server::reload_handle`].
#[derive(Clone)]
pub struct ReloadHandle {
    shared: Arc<Shared>,
}

impl ReloadHandle {
    /// Same contract as [`Server::reload`].
    pub fn reload(&self, artifact: &ModelArtifact) -> Result<()> {
        self.shared.model.reload(artifact)
    }

    /// Total reloads applied to the underlying model so far.
    pub fn reload_count(&self) -> u64 {
        self.shared.model.reload_count()
    }
}

/// Control-plane access to a running server, detached from the
/// [`Server`] value's lifetime — what the admin socket
/// ([`crate::serve::admin`]) serves its `stats`/`reload`/`drain`
/// commands through.
#[derive(Clone)]
pub struct AdminHandle {
    shared: Arc<Shared>,
    started: Instant,
}

impl AdminHandle {
    /// Point-in-time report over everything served so far (same wall
    /// clock as [`Server::stats_snapshot`]).
    pub fn stats(&self) -> ServeReport {
        let wall = self.started.elapsed().as_secs_f64();
        let reloads = self.shared.model.reload_count();
        let mut r = self.shared.stats.lock().unwrap().report(wall, reloads);
        r.info = Some(self.shared.info());
        r
    }

    /// Render everything the admin socket knows in Prometheus text
    /// exposition format: serving counters/timers/histograms, SLO
    /// gauges, plus health, primitive-profiler and process-resource
    /// families when their monitors are installed.
    pub fn prometheus(&self) -> String {
        let wall = self.started.elapsed().as_secs_f64();
        let reloads = self.shared.model.reload_count();
        let queue_depth = self.shared.state.lock().unwrap().depth;
        let info = self.shared.info();
        let mut out = String::new();
        self.shared
            .stats
            .lock()
            .unwrap()
            .prometheus_into(&mut out, wall, reloads, queue_depth, Some(&info));
        if let Some(h) = health::current() {
            crate::serve::metrics::prometheus_health_into(&mut out, &h.evaluate());
        }
        if let Some(p) = crate::telemetry::current() {
            crate::serve::metrics::prometheus_profiler_into(&mut out, &p);
        }
        if let Some(r) = crate::telemetry::resource::snapshot() {
            crate::serve::metrics::prometheus_resource_into(&mut out, &r);
        }
        out
    }

    /// Same contract as [`Server::reload`]: atomic hot swap, in-flight
    /// batches finish on the generation they pinned.
    pub fn reload(&self, artifact: &ModelArtifact) -> Result<()> {
        self.shared.model.reload(artifact)
    }

    pub fn reload_count(&self) -> u64 {
        self.shared.model.reload_count()
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.state.lock().unwrap().depth
    }

    pub fn accepting(&self) -> bool {
        self.shared.state.lock().unwrap().accepting
    }

    /// Stop intake and block until every accepted request has been
    /// responded to — queue empty *and* no batch in flight. Safe to call
    /// more than once (and concurrently with [`Server::shutdown`], which
    /// then merely joins already-exiting workers). Returns the final
    /// report; no accepted response is lost.
    pub fn drain(&self) -> ServeReport {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.accepting = false;
        }
        // Draining is observable the instant intake stops — a concurrent
        // `admin health` poller sees the transition while this call still
        // blocks on in-flight work.
        if let Some((h, _)) = &self.shared.hb {
            h.set_draining();
        }
        self.shared.cv.notify_all();
        let mut st = self.shared.state.lock().unwrap();
        while st.depth > 0 || st.in_flight > 0 {
            st = self.shared.cv.wait(st).unwrap();
        }
        drop(st);
        self.stats()
    }
}

fn worker_loop(shared: &Shared, widx: usize, tx: &mpsc::Sender<Response>) {
    let classes = shared.model.classes();
    let max_batch = shared.opts.max_batch;
    let step_dim = shared.model.seq_step_dim();
    // Per-worker reusable buffers: the padded batch input and the forward
    // plan's activation scratch both grow to their high-water mark during
    // warm-up and are then reused — the steady-state path performs no
    // per-request allocation (asserted by the scratch tests; the owned
    // per-response logits row is the one API-mandated copy).
    let mut scratch = ServeScratch::new();
    let mut xbuf: Vec<f32> = Vec::new();
    let mut lens: Vec<usize> = Vec::new();
    // Tracer capture, once per worker thread (the profiler pattern): when
    // tracing is off the per-batch cost below is a single branch on this
    // `None`. Gated on the server's own opt-in too, so a server that did
    // not ask for tracing never writes into a tracer some other component
    // installed. Each worker owns one pre-allocated span ring, so
    // recording never contends across workers.
    let tracing = if shared.opts.trace {
        trace::current().map(|t| {
            let ring = t.ring();
            (t, ring)
        })
    } else {
        None
    };
    let hb = shared.hb.as_ref();
    let slo_on = shared.opts.slo.is_some();
    loop {
        // Take up to max_batch requests from one length bucket, or exit
        // once draining is done.
        let (taken, len_bucket, depth_after) = {
            let mut st = shared.state.lock().unwrap();
            let (taken, len_bucket): (Vec<Pending>, usize) = loop {
                while st.depth == 0 {
                    if !st.accepting {
                        // Last worker out marks the pool gone: retired
                        // groups are exempt from stall detection, and a
                        // fully drained pool means the server is going
                        // away — surface that as Draining.
                        if let Some((h, g)) = hb {
                            g.retire();
                            h.set_draining();
                        }
                        return;
                    }
                    // An idle worker is healthy, not stalled: with a
                    // heartbeat group registered, wake periodically so
                    // the beat keeps advancing while the queue is empty.
                    match hb {
                        Some((_, g)) => {
                            let (guard, _timeout) = shared
                                .cv
                                .wait_timeout(st, Duration::from_millis(500))
                                .unwrap();
                            st = guard;
                            g.beat(widx);
                        }
                        None => st = shared.cv.wait(st).unwrap(),
                    }
                }
                // Batching delay: wait up to the configured window for
                // some length bucket to fill before dispatching a partial
                // batch. New arrivals (and shutdown) wake the wait; a
                // full bucket or the deadline ends it.
                if shared.opts.wait_for_fill_us > 0
                    && st.max_bucket_depth() < max_batch
                    && st.accepting
                {
                    let deadline =
                        Instant::now() + Duration::from_micros(shared.opts.wait_for_fill_us);
                    while st.max_bucket_depth() < max_batch && st.accepting {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        let (guard, _timeout) =
                            shared.cv.wait_timeout(st, deadline - now).unwrap();
                        st = guard;
                    }
                    // Another worker may have drained the queue while this
                    // one waited — go back to waiting for work.
                    if st.depth == 0 {
                        continue;
                    }
                }
                // Dispatch the length bucket whose front request has
                // waited longest; the group stays homogeneous so one
                // prefix run serves the whole batch.
                let lb = st.oldest_bucket().expect("depth > 0 implies a non-empty bucket");
                let taken: Vec<Pending> = {
                    let q = st.queues.get_mut(&lb).unwrap();
                    let k = q.len().min(max_batch);
                    q.drain(..k).collect()
                };
                st.depth -= taken.len();
                st.in_flight += taken.len();
                break (taken, lb);
            };
            (taken, len_bucket, st.depth)
        };
        // The dequeue instant splits each request's latency into its two
        // stages: enqueue→here is queue wait, the rest is batch execution.
        let dequeued = Instant::now();
        let fill = taken.len();
        let bucket = shared.model.bucket_for(fill);
        // Row width under this dispatch: the length bucket's padded
        // sequence for sequence models, the fixed input otherwise.
        let row = match step_dim {
            None => shared.model.input_dim(),
            Some(c) => len_bucket * c,
        };
        // Pad to the bucket with zero rows (and zero time-padding past
        // each sequence's true length); padded outputs are computed and
        // then masked (dropped) below — bit-identical real rows either way.
        if xbuf.len() < bucket * row {
            xbuf.resize(bucket * row, 0.0);
        }
        let x = &mut xbuf[..bucket * row];
        x.fill(0.0);
        for (i, r) in taken.iter().enumerate() {
            x[i * row..i * row + r.input.len()].copy_from_slice(&r.input);
        }
        // Reload-stall probe: one timed read-lock acquisition on the
        // weight set. Nanoseconds normally; a concurrent hot-reload
        // write-swap shows up here, attributing the stall to the reload
        // rather than inflating apparent compute.
        let reload_stall_secs =
            if slo_on { shared.model.weight_pin_wait_secs() } else { 0.0 };
        let t_fwd = Instant::now();
        let logits = match step_dim {
            None => shared.model.forward_with(bucket, x, &mut scratch),
            Some(_) => {
                lens.clear();
                lens.extend(taken.iter().map(|r| r.len));
                lens.resize(bucket, len_bucket); // padded tail rows
                shared.model.forward_seq_with(bucket, len_bucket, &lens, x, &mut scratch)
            }
        };
        let done = Instant::now();
        let compute_secs = done.duration_since(t_fwd).as_secs_f64();
        let mut lats = Vec::with_capacity(fill);
        let mut waits = Vec::with_capacity(fill);
        for (i, r) in taken.iter().enumerate() {
            let latency = done.duration_since(r.enqueued).as_secs_f64();
            lats.push(latency);
            waits.push(dequeued.duration_since(r.enqueued).as_secs_f64());
            // Send failures mean the collector hung up early; serving
            // statistics still account the work.
            let _ = tx.send(Response {
                id: r.id,
                logits: logits[i * classes..(i + 1) * classes].to_vec(),
                latency_secs: latency,
                bucket,
                fill,
                len_bucket,
            });
        }
        // Span recording, off the compute path: one batch group (the
        // batch itself, its form/compute stages, and the forward pass's
        // per-layer marks) plus one request group per *sampled* member
        // linking back to the batch via `link`. Groups are stack-built
        // `Copy` values; the ring push is the only shared-state touch.
        if let Some((tr, ring)) = &tracing {
            if taken.iter().any(|r| tr.sampled(r.id)) {
                let tid = widx as u32;
                let bid = tr.next_batch_id();
                let mut bg = TraceGroup::new(0);
                let (bs, bd) = tr.span_us(dequeued, done);
                bg.push(SpanEvent {
                    kind: SpanKind::Batch,
                    label: "",
                    trace_id: bid,
                    tid,
                    start_us: bs,
                    dur_us: bd,
                    a: bucket as u32,
                    b: fill as u32,
                });
                let (fs, fd) = tr.span_us(dequeued, t_fwd);
                bg.push(SpanEvent {
                    kind: SpanKind::BatchForm,
                    label: "",
                    trace_id: bid,
                    tid,
                    start_us: fs,
                    dur_us: fd,
                    a: len_bucket as u32,
                    b: 0,
                });
                let (cs, cd) = tr.span_us(t_fwd, done);
                bg.push(SpanEvent {
                    kind: SpanKind::BatchCompute,
                    label: "",
                    trace_id: bid,
                    tid,
                    start_us: cs,
                    dur_us: cd,
                    a: bucket as u32,
                    b: len_bucket as u32,
                });
                for m in &scratch.layer_marks {
                    let (ls, ld) = tr.span_us(m.start, m.start + m.dur);
                    bg.push(SpanEvent {
                        kind: SpanKind::Layer,
                        label: m.label,
                        trace_id: bid,
                        tid,
                        start_us: ls,
                        dur_us: ld,
                        a: m.index,
                        b: 0,
                    });
                }
                ring.push(bg);
                for r in &taken {
                    if !tr.sampled(r.id) {
                        continue;
                    }
                    let mut g = TraceGroup::new(bid);
                    let (rs, rd) = tr.span_us(r.enqueued, done);
                    g.push(SpanEvent {
                        kind: SpanKind::Request,
                        label: "",
                        trace_id: r.id,
                        tid,
                        start_us: rs,
                        dur_us: rd,
                        a: bucket as u32,
                        b: len_bucket as u32,
                    });
                    let (qs, qd) = tr.span_us(r.enqueued, dequeued);
                    g.push(SpanEvent {
                        kind: SpanKind::QueueWait,
                        label: "",
                        trace_id: r.id,
                        tid,
                        start_us: qs,
                        dur_us: qd,
                        a: len_bucket as u32,
                        b: 0,
                    });
                    let (is, id) = tr.span_us(dequeued, done);
                    g.push(SpanEvent {
                        kind: SpanKind::InBatch,
                        label: "",
                        trace_id: r.id,
                        tid,
                        start_us: is,
                        dur_us: id,
                        a: bucket as u32,
                        b: fill as u32,
                    });
                    ring.push(g);
                }
            }
        }
        crate::log_trace!(
            "batch b{} t{} fill {} depth {} compute {:.3} ms",
            bucket,
            len_bucket,
            fill,
            depth_after,
            compute_secs * 1e3
        );
        // SLO classification, outside the stats lock: met/violated per
        // request, with violations attributed to their dominant stage.
        let outcomes: Option<Vec<SloOutcome>> = slo_on.then(|| {
            taken
                .iter()
                .zip(lats.iter().zip(&waits))
                .map(|(r, (&lat, &wait))| {
                    classify(r.deadline_secs, lat, wait, compute_secs, reload_stall_secs)
                })
                .collect()
        });
        {
            let mut stats = shared.stats.lock().unwrap();
            stats.record_batch(
                bucket,
                len_bucket,
                fill,
                depth_after,
                &lats,
                &waits,
                compute_secs,
            );
            if let Some(outcomes) = &outcomes {
                stats.record_slo(bucket, len_bucket, outcomes);
            }
            // Feed the health monitor while the stats lock is held so the
            // burn-rate gauge it sees is the one this batch produced.
            if let Some((h, g)) = hb {
                g.beat(widx);
                h.observe_queue_depth(depth_after as u64);
                if let Some(s) = stats.slo() {
                    h.observe_burn_rate(s.burn_rate_short());
                }
            }
        }
        // The batch is fully accounted: release its in-flight claim and
        // wake anything blocked in `AdminHandle::drain`.
        {
            let mut st = shared.state.lock().unwrap();
            st.in_flight -= fill;
        }
        shared.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model::InferenceModel;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    fn mlp_model(max_batch: usize) -> InferenceModel {
        InferenceModel::new_mlp(&[10, 12, 4], max_batch, 1, false, &mut Rng::new(5))
    }

    #[test]
    fn co_batched_responses_bit_identical_to_solo() {
        // Submit a burst with one worker so requests genuinely co-batch,
        // then check every response row against a solo batch-1 forward of
        // the same input — padding/masking must be invisible.
        let model = mlp_model(8);
        let oracle = mlp_model(8); // same seed ⇒ identical weights
        let mut rng = Rng::new(6);
        let inputs: Vec<Vec<f32>> = (0..13).map(|_| rng.vec_f32(10, -1.0, 1.0)).collect();
        let (server, rx) = Server::start(model, ServeOpts { max_batch: 8, workers: 1, ..ServeOpts::default() });
        // Atomic burst: the single worker necessarily sees depth 13 and
        // co-batches (8 then 5→bucket 8, or some split — never 13 × b1).
        let ids: Vec<u64> = server.submit_all(inputs.iter().cloned());
        let report = server.shutdown();
        let responses: Vec<Response> = rx.iter().collect();
        assert_eq!(report.requests, 13);
        assert_eq!(responses.len(), 13);
        let by_id: BTreeMap<u64, &Response> = responses.iter().map(|r| (r.id, r)).collect();
        let mut co_batched = 0usize;
        for (id, x) in ids.iter().zip(&inputs) {
            let r = by_id[id];
            let solo = oracle.forward(1, x);
            assert_eq!(r.logits, solo, "request {} logits differ from solo batch-1", id);
            if r.bucket > 1 {
                co_batched += 1;
            }
        }
        // The burst outran the single worker, so most requests co-batched.
        assert!(co_batched > 0, "burst must produce at least one multi-request batch");
    }

    #[test]
    fn shutdown_drains_queue_no_lost_or_duplicated_responses() {
        // Flood the queue far beyond what the workers can clear before
        // shutdown is requested; drain semantics must still answer every
        // request exactly once.
        let model = mlp_model(4);
        let (server, rx) = Server::start(model, ServeOpts { max_batch: 4, workers: 3, ..ServeOpts::default() });
        let mut rng = Rng::new(7);
        let n = 200u64;
        for _ in 0..n {
            server.submit(rng.vec_f32(10, -1.0, 1.0));
        }
        assert_eq!(server.submitted(), n);
        let report = server.shutdown(); // queue almost certainly non-empty here
        let mut seen = BTreeMap::new();
        for r in rx.iter() {
            *seen.entry(r.id).or_insert(0usize) += 1;
        }
        assert_eq!(seen.len() as u64, n, "every request answered");
        assert!(seen.values().all(|&c| c == 1), "no duplicated responses");
        assert_eq!(
            seen.keys().copied().collect::<Vec<u64>>(),
            (0..n).collect::<Vec<u64>>(),
            "ids are exactly the submitted ones"
        );
        assert_eq!(report.requests, n as usize, "stats agree with the channel");
        // Batch accounting is consistent: per-bucket requests sum to n.
        let hist_requests: f64 = report
            .batch_fill
            .iter()
            .map(|&(b, batches, fill)| fill * (b * batches) as f64)
            .sum();
        assert!((hist_requests - n as f64).abs() < 1e-6, "{} vs {}", hist_requests, n);
        // Stage tracing: every batch timed its forward, and a request's
        // queue wait is a prefix of its latency, so the means must order.
        assert!(report.compute_mean_ms > 0.0, "forward compute was timed");
        assert!(
            report.queue_wait_mean_ms <= report.mean_ms + 1e-9,
            "queue wait {} must not exceed end-to-end latency {}",
            report.queue_wait_mean_ms,
            report.mean_ms
        );
        assert_eq!(report.bucket_stages.len(), report.batch_fill.len());
    }

    #[test]
    fn empty_shutdown_is_clean() {
        let (server, rx) = Server::start(mlp_model(2), ServeOpts { max_batch: 2, workers: 2, ..ServeOpts::default() });
        let report = server.shutdown();
        assert_eq!(report.requests, 0);
        assert_eq!(rx.iter().count(), 0, "channel disconnects with no responses");
    }

    #[test]
    fn wait_for_fill_coalesces_a_trickle_and_still_drains() {
        // One worker, a generous fill window: requests submitted one by
        // one (each submit wakes the waiting worker, which keeps waiting
        // because the bucket is not full) must coalesce into fuller
        // batches than greedy dispatch would produce, and a partial
        // bucket must still dispatch — nothing hangs, nothing is lost.
        let model = mlp_model(4);
        let opts =
            ServeOpts { max_batch: 4, workers: 1, wait_for_fill_us: 200_000, ..ServeOpts::default() };
        let (server, rx) = Server::start(model, opts);
        let mut rng = Rng::new(17);
        for _ in 0..6 {
            server.submit(rng.vec_f32(10, -1.0, 1.0));
        }
        let report = server.shutdown();
        let responses: Vec<Response> = rx.iter().collect();
        assert_eq!(responses.len(), 6, "fill window must not lose requests");
        assert_eq!(report.requests, 6);
        // 6 requests into a 4-bucket ladder: the window holds the worker
        // until the bucket fills, so the first batch carries 4 requests
        // (greedy dispatch with one worker would almost surely start with
        // a batch of 1) and the 2-request remainder dispatches at
        // shutdown without waiting out the window.
        let mut fills: Vec<usize> = responses.iter().map(|r| r.fill).collect();
        fills.sort_unstable();
        fills.dedup();
        assert_eq!(fills, vec![2, 4], "one full bucket + the drained remainder");
    }

    #[test]
    fn full_bucket_dispatches_without_waiting_out_the_window() {
        // A burst that already fills max_batch must not pay the window.
        let model = mlp_model(4);
        // A window so large that waiting it out would trip the test's own
        // timeout many times over.
        let opts = ServeOpts {
            max_batch: 4,
            workers: 1,
            wait_for_fill_us: 60_000_000,
            ..ServeOpts::default()
        };
        let (server, rx) = Server::start(model, opts);
        let mut rng = Rng::new(19);
        let t0 = Instant::now();
        server.submit_all((0..8).map(|_| rng.vec_f32(10, -1.0, 1.0)));
        let _ = server.shutdown(); // shutdown also cuts any residual wait
        let responses: Vec<Response> = rx.iter().collect();
        assert_eq!(responses.len(), 8);
        assert!(responses.iter().all(|r| r.fill == 4), "two full buckets");
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "full buckets and shutdown must not wait out the fill window"
        );
    }

    #[test]
    fn hot_reload_swaps_weights_between_batches_without_losing_requests() {
        use crate::coordinator::trainer::Model;
        use crate::modelio::{Arch, ModelArtifact, TrainMeta};
        let sizes = [10usize, 12, 4];
        let model = InferenceModel::new_mlp(&sizes, 4, 1, false, &mut Rng::new(5));
        let old_oracle = InferenceModel::new_mlp(&sizes, 4, 1, false, &mut Rng::new(5));
        // The replacement weights: a differently-seeded model.
        let donor =
            crate::coordinator::trainer::MlpModel::new(&sizes, 4, 1, &mut Rng::new(99));
        let art = ModelArtifact::new(
            Arch::Mlp { sizes: sizes.to_vec() },
            TrainMeta::fresh(99),
            donor.export_weights(),
        );
        let new_oracle = InferenceModel::from_artifact(&art, 4, 1, false).unwrap();

        let (server, rx) = Server::start(
            model,
            ServeOpts { max_batch: 4, workers: 2, ..ServeOpts::default() },
        );
        let mut rng = Rng::new(7);
        let inputs: Vec<Vec<f32>> = (0..60).map(|_| rng.vec_f32(10, -1.0, 1.0)).collect();
        // Interleave submissions with a mid-stream reload: batches in
        // flight finish on whatever generation they pinned, later batches
        // use the new weights — every response must match exactly one of
        // the two oracles, bit for bit (a torn read would match neither).
        let ids: Vec<u64> = server.submit_all(inputs[..30].iter().cloned());
        server.reload(&art).unwrap();
        let ids2: Vec<u64> = server.submit_all(inputs[30..].iter().cloned());
        let report = server.shutdown();
        let responses: Vec<Response> = rx.iter().collect();
        assert_eq!(responses.len(), 60, "reload must not drop or duplicate requests");
        assert_eq!(report.requests, 60);
        assert_eq!(report.reloads, 1, "the swap count lands in the metrics");
        let by_id: BTreeMap<u64, &Response> = responses.iter().map(|r| (r.id, r)).collect();
        let mut matched_old = 0usize;
        let mut matched_new = 0usize;
        for (id, x) in ids.iter().chain(&ids2).zip(&inputs) {
            let r = by_id[id];
            let old = old_oracle.forward(1, x);
            let new = new_oracle.forward(1, x);
            if r.logits == old {
                matched_old += 1;
            } else if r.logits == new {
                matched_new += 1;
            } else {
                panic!("response {} matches neither weight generation", id);
            }
        }
        assert_eq!(matched_old + matched_new, 60);
        // Everything submitted after the reload must be on the new set
        // (the swap happened strictly before those requests entered the
        // queue).
        for (id, x) in ids2.iter().zip(&inputs[30..]) {
            let r = by_id[id];
            assert_eq!(r.logits, new_oracle.forward(1, x), "post-reload request {}", id);
        }
        assert!(matched_new >= 30, "at least the post-reload half is on the new weights");
    }

    #[test]
    #[should_panic(expected = "request shape mismatch")]
    fn wrong_shape_rejected() {
        let (server, _rx) = Server::start(mlp_model(2), ServeOpts { max_batch: 2, workers: 1, ..ServeOpts::default() });
        server.submit(vec![0.0; 3]);
    }

    fn rnn_model(seed: u64, max_batch: usize) -> InferenceModel {
        let spec =
            crate::coordinator::rnn::RnnSpec { c: 5, k: 8, t: 8, classes: 3, layers: 2 };
        InferenceModel::new_rnn(&spec, max_batch, 1, false, &mut Rng::new(seed))
    }

    #[test]
    fn mixed_length_backlog_rides_the_ladder_and_answers_everything() {
        // 50 mixed-length requests — far beyond the top batch bucket —
        // into one worker: the backlog must ride both ladders (length
        // bucket x batch bucket), a batch must never mix length buckets,
        // and every response must be bit-identical to a solo batch-1 run
        // at the request's own length.
        let c = 5usize;
        let model = rnn_model(23, 8);
        let oracle = rnn_model(23, 8); // same seed ⇒ identical weights
        let mut rng = Rng::new(24);
        let reqs: Vec<Vec<f32>> =
            (0..50).map(|i| rng.vec_f32((1 + i % 8) * c, -1.0, 1.0)).collect();
        let (server, rx) = Server::start(
            model,
            ServeOpts { max_batch: 8, workers: 1, ..ServeOpts::default() },
        );
        let ids = server.submit_all(reqs.iter().cloned());
        let report = server.shutdown();
        let responses: Vec<Response> = rx.iter().collect();
        assert_eq!(responses.len(), 50, "every mixed-length request answered");
        assert_eq!(report.requests, 50);
        let by_id: BTreeMap<u64, &Response> = responses.iter().map(|r| (r.id, r)).collect();
        let mut co_batched = 0usize;
        for (id, x) in ids.iter().zip(&reqs) {
            let r = by_id[id];
            let len = x.len() / c;
            let lb = oracle.len_bucket_for(len);
            assert_eq!(r.len_bucket, lb, "request {} dispatched in its own length bucket", id);
            let mut solo = vec![0.0f32; lb * c];
            solo[..x.len()].copy_from_slice(x);
            let want = oracle.forward_seq(1, lb, &[len], &solo);
            assert_eq!(r.logits, want, "request {} (len {}) differs from its solo run", id, len);
            if r.fill > 1 {
                co_batched += 1;
            }
        }
        assert!(co_batched > 0, "the backlog must have co-batched same-length requests");
        // The report splits the run by length bucket (lengths 1..=8 land
        // in buckets 1, 2, 4, 8) and its request counts add back up.
        assert_eq!(report.len_buckets.len(), 4, "{:?}", report.len_buckets);
        let split_total: usize = report.len_buckets.iter().map(|&(_, _, n, _)| n).sum();
        assert_eq!(split_total, 50);
    }

    #[test]
    fn full_length_sequence_traffic_matches_the_fixed_path() {
        // All-full-length requests collapse to one length bucket (the
        // arch's t) and must reproduce the fixed-shape forward exactly.
        let model = rnn_model(29, 4);
        let oracle = rnn_model(29, 4);
        let dim = oracle.input_dim();
        let mut rng = Rng::new(30);
        let reqs: Vec<Vec<f32>> = (0..10).map(|_| rng.vec_f32(dim, -1.0, 1.0)).collect();
        let (server, rx) = Server::start(
            model,
            ServeOpts { max_batch: 4, workers: 2, ..ServeOpts::default() },
        );
        let ids = server.submit_all(reqs.iter().cloned());
        let _ = server.shutdown();
        let by_id: BTreeMap<u64, Response> = rx.iter().map(|r| (r.id, r)).collect();
        for (id, x) in ids.iter().zip(&reqs) {
            let r = &by_id[id];
            assert_eq!(r.len_bucket, 8, "full-length requests land in the top bucket");
            assert_eq!(r.logits, oracle.forward(1, x), "request {}", id);
        }
    }

    #[test]
    #[should_panic(expected = "request shape mismatch")]
    fn seq_request_with_partial_step_rejected() {
        let (server, _rx) = Server::start(
            rnn_model(31, 2),
            ServeOpts { max_batch: 2, workers: 1, ..ServeOpts::default() },
        );
        server.submit(vec![0.0; 2 * 5 + 1]); // 2 steps and a bit
    }

    #[test]
    #[should_panic(expected = "request shape mismatch")]
    fn seq_request_longer_than_capacity_rejected() {
        let (server, _rx) = Server::start(
            rnn_model(33, 2),
            ServeOpts { max_batch: 2, workers: 1, ..ServeOpts::default() },
        );
        server.submit(vec![0.0; 9 * 5]); // t = 8
    }

    #[test]
    fn trace_sampling_is_deterministic_and_spans_well_nest() {
        use crate::telemetry::trace::well_nested;
        let _g = crate::telemetry::test_lock();
        let tr = trace::install(4, 256);
        let model = mlp_model(8);
        let (server, rx) = Server::start(
            model,
            ServeOpts { max_batch: 8, workers: 2, trace: true, ..ServeOpts::default() },
        );
        let mut rng = Rng::new(41);
        for _ in 0..40 {
            server.submit(rng.vec_f32(10, -1.0, 1.0));
        }
        let _ = server.shutdown();
        assert_eq!(rx.iter().count(), 40);
        let d = tr.drain();
        trace::uninstall();
        // Ids are minted sequentially at submit, so with sample_every=4
        // the traced set is exactly {0, 4, 8, ..., 36} — deterministic
        // for a fixed load schedule, whatever the worker interleaving.
        let sampled: std::collections::BTreeSet<u64> = d
            .groups
            .iter()
            .filter(|g| g.find(SpanKind::Request).is_some())
            .map(|g| g.trace_id())
            .collect();
        let want: std::collections::BTreeSet<u64> = (0..40).filter(|i| i % 4 == 0).collect();
        assert_eq!(sampled, want);
        for g in d.groups.iter().filter(|g| g.find(SpanKind::Request).is_some()) {
            // Every sampled request carries its complete, well-nested
            // enqueue→respond span set — never a partial trace.
            assert_eq!(g.spans().len(), 3, "request group is complete");
            let req = g.find(SpanKind::Request).unwrap();
            let qw = g.find(SpanKind::QueueWait).unwrap();
            let ib = g.find(SpanKind::InBatch).unwrap();
            assert!(well_nested(req, qw), "queue wait inside request");
            assert!(well_nested(req, ib), "batch residence inside request");
            assert!(qw.end_us() <= ib.start_us, "wait ends where batching starts");
            // And the flow link points at a batch group that exists.
            assert!(g.link != 0, "request group links to its batch");
            assert!(
                d.groups
                    .iter()
                    .any(|b| b.find(SpanKind::Batch).is_some() && b.trace_id() == g.link),
                "linked batch group present"
            );
        }
        // Batch groups carry the form/compute stage spans nested in the
        // batch span.
        for g in d.groups.iter().filter(|g| g.find(SpanKind::Batch).is_some()) {
            let b = g.find(SpanKind::Batch).unwrap();
            let form = g.find(SpanKind::BatchForm).unwrap();
            let compute = g.find(SpanKind::BatchCompute).unwrap();
            assert!(well_nested(b, form) && well_nested(b, compute));
            assert!(
                g.find(SpanKind::Layer).is_some(),
                "per-layer compute spans recorded"
            );
        }
        assert_eq!(d.dropped_groups, 0, "ring capacity was not exceeded");
    }

    #[test]
    fn traced_serving_is_bit_identical_to_untraced() {
        // The tracer extends the profiler's contract: enabling it may
        // change timing side channels only. Same seed, same burst —
        // every response must match bitwise with and without it.
        let _g = crate::telemetry::test_lock();
        let run = |traced: bool| -> BTreeMap<u64, Vec<f32>> {
            if traced {
                trace::install(2, 128);
            } else {
                trace::uninstall();
            }
            let model = mlp_model(4);
            let (server, rx) = Server::start(
                model,
                ServeOpts { max_batch: 4, workers: 2, trace: traced, ..ServeOpts::default() },
            );
            let mut rng = Rng::new(43);
            server.submit_all((0..20).map(|_| rng.vec_f32(10, -1.0, 1.0)));
            let _ = server.shutdown();
            trace::uninstall();
            rx.iter().map(|r| (r.id, r.logits)).collect()
        };
        assert_eq!(run(true), run(false), "tracing must not change the logits");
    }

    #[test]
    fn admin_drain_answers_everything_and_stops_intake() {
        let model = mlp_model(4);
        let (server, rx) = Server::start(
            model,
            ServeOpts { max_batch: 4, workers: 2, ..ServeOpts::default() },
        );
        let mut rng = Rng::new(47);
        for _ in 0..100 {
            server.submit(rng.vec_f32(10, -1.0, 1.0));
        }
        let admin = server.admin_handle();
        let report = admin.drain();
        // Drain blocks until queue empty AND no batch in flight, so the
        // report already accounts every accepted request.
        assert_eq!(report.requests, 100, "drain waited for in-flight batches");
        assert_eq!(admin.queue_depth(), 0);
        assert!(!admin.accepting());
        // Intake is closed: the non-panicking submit refuses...
        assert!(server.try_submit(rng.vec_f32(10, -1.0, 1.0)).is_none());
        // ...and a second drain is an idempotent no-op.
        assert_eq!(admin.drain().requests, 100);
        let final_report = server.shutdown();
        assert_eq!(final_report.requests, 100);
        let responses: Vec<Response> = rx.iter().collect();
        assert_eq!(responses.len(), 100, "no response lost across the drain");
    }

    #[test]
    fn slo_deadlines_classify_and_land_in_the_report() {
        // An impossible per-request deadline (0 ms) must violate; the
        // server-default deadline (60 s) must be met. Violations carry a
        // stage attribution, and the whole block lands in the report.
        let model = mlp_model(4);
        let (server, rx) = Server::start(
            model,
            ServeOpts {
                max_batch: 4,
                workers: 1,
                slo: Some(SloSpec { latency_ms: 60_000.0, objective: 0.9 }),
                ..ServeOpts::default()
            },
        );
        let mut rng = Rng::new(53);
        for _ in 0..6 {
            server.try_submit(rng.vec_f32(10, -1.0, 1.0)).unwrap();
        }
        for _ in 0..2 {
            server
                .try_submit_with_deadline(rng.vec_f32(10, -1.0, 1.0), Some(0.0))
                .unwrap();
        }
        let report = server.shutdown();
        assert_eq!(rx.iter().count(), 8);
        let slo = report.slo.expect("SLO configured ⇒ summary present");
        assert_eq!(slo.total, 8);
        assert_eq!(slo.met, 6, "only the 0 ms-deadline requests can violate");
        assert_eq!(slo.violations(), 2);
        assert_eq!(
            slo.viol_queue_wait + slo.viol_compute + slo.viol_reload,
            2,
            "every violation is attributed to exactly one stage"
        );
        assert!((slo.attainment - 0.75).abs() < 1e-12);
        let json = report.to_json().to_string_compact();
        assert!(json.contains("\"slo_attainment\""), "summary serialises: {}", json);
    }

    #[test]
    fn no_slo_configured_means_no_slo_block_and_no_deadline() {
        let model = mlp_model(4);
        let (server, rx) =
            Server::start(model, ServeOpts { max_batch: 4, workers: 1, ..ServeOpts::default() });
        let mut rng = Rng::new(59);
        server.submit(rng.vec_f32(10, -1.0, 1.0));
        let report = server.shutdown();
        assert_eq!(rx.iter().count(), 1);
        assert!(report.slo.is_none());
        assert!(!report.to_json().to_string_compact().contains("\"slo\""));
    }

    #[test]
    fn reports_carry_server_info() {
        let model = mlp_model(4);
        let (server, _rx) =
            Server::start(model, ServeOpts { max_batch: 4, workers: 2, ..ServeOpts::default() });
        let snap = server.stats_snapshot();
        let info = snap.info.expect("every report path attaches the server info");
        assert_eq!(info.workers, 2);
        assert_eq!(info.max_batch, 4);
        assert_eq!(*info.buckets.last().unwrap(), 4);
        assert!(info.arch.starts_with("mlp"), "arch tag: {}", info.arch);
        let admin = server.admin_handle();
        assert!(admin.stats().info.is_some());
        assert!(server.shutdown().info.is_some());
    }

    #[test]
    fn health_monitored_server_walks_ready_then_draining() {
        use crate::telemetry::health::{self, HealthState, HealthThresholds};
        let _g = crate::telemetry::test_lock();
        health::install(HealthThresholds::default());
        let h = health::current().unwrap();
        let model = mlp_model(4);
        let (server, rx) = Server::start(
            model,
            ServeOpts { max_batch: 4, workers: 2, health: true, ..ServeOpts::default() },
        );
        // Serve a little traffic so every worker has beaten at least once.
        let mut rng = Rng::new(61);
        server.submit_all((0..16).map(|_| rng.vec_f32(10, -1.0, 1.0)));
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while h.evaluate().state != HealthState::Ready {
            assert!(std::time::Instant::now() < deadline, "never reached Ready");
            std::thread::sleep(Duration::from_millis(10));
        }
        let report = server.shutdown();
        assert_eq!(report.requests, 16);
        assert_eq!(rx.iter().count(), 16);
        // Shutdown marks the pool draining and retires the group.
        let snap = h.evaluate();
        assert_eq!(snap.state, HealthState::Draining);
        assert!(!snap.groups.iter().any(|g| g.name == "serve" && g.active));
        health::uninstall();
    }

    #[test]
    fn slo_and_health_instrumentation_is_bit_identical_to_plain() {
        // Same contract as tracing: SLO accounting plus health
        // monitoring may change timing side channels only.
        let _g = crate::telemetry::test_lock();
        let run = |instrumented: bool| -> BTreeMap<u64, Vec<f32>> {
            use crate::telemetry::health::{self, HealthThresholds};
            if instrumented {
                health::install(HealthThresholds::default());
            } else {
                health::uninstall();
            }
            let slo = instrumented.then(SloSpec::default);
            let model = mlp_model(4);
            let (server, rx) = Server::start(
                model,
                ServeOpts {
                    max_batch: 4,
                    workers: 2,
                    slo,
                    health: instrumented,
                    ..ServeOpts::default()
                },
            );
            let mut rng = Rng::new(67);
            server.submit_all((0..20).map(|_| rng.vec_f32(10, -1.0, 1.0)));
            let _ = server.shutdown();
            health::uninstall();
            rx.iter().map(|r| (r.id, r.logits)).collect()
        };
        assert_eq!(run(true), run(false), "SLO/health must not change the logits");
    }
}
