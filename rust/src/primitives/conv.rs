//! Direct convolutions via the batch-reduce GEMM kernel (paper §3.2,
//! Algorithms 3/4) plus the Figure-1 baselines (im2col + large GEMM, and
//! small-GEMM loop nests without batch reduction).
//!
//! Layouts (§3.2.1), with physical spatial padding so every BRGEMM operand
//! block is a plain offset:
//! ```text
//!   input   I[N][Cb][H+2p][W+2p][bc]
//!   weights W[Kb][Cb][R][S][bc][bk]
//!   output  O[N][Kb][P][Q][bk]
//! ```
//! One forward work item = a `bq×bk` strip of output pixels: a single
//! BRGEMM call with batch `R·S·Cb` reduces every (tap, input-feature-block)
//! contribution into the strip — saving the `(R·S·Cb)−1` accumulator
//! load/stores a specialized kernel would otherwise need (§3.2.2).
//!
//! Backward-by-data is the "dual convolution" of [27]: the same forward
//! loop nest over (C↔K)-transposed, 180°-rotated weights and a re-padded
//! dO. Weight update reduces over (mini-batch × output rows) in one BRGEMM
//! chain, reading activations transposed in place via the kernel's
//! `a_kstride` (stride-aware, so strided convolutions need no reformat
//! beyond the per-row channel transpose).

use crate::brgemm::{BrgemmDesc, BrgemmKernel, Epilogue, Gemm};
use crate::primitives::eltwise::Act;
use crate::primitives::partition::{Partition2d, Strategy};
use crate::telemetry::{self, Pass, PrimSlot};
use crate::tensor::layout;
use crate::util::num::largest_divisor_le;
use crate::util::pool::{parallel_chunks_mut, parallel_region, SharedMut};
use std::sync::Arc;
use std::time::Instant;

/// How the spatially-collapsed forward path (legal for 1×1/stride-1/no-pad
/// layers, where P×Q is one contiguous pixel dimension) is driven.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlatSpatial {
    /// Use it when legal, with an automatically picked pixel strip.
    Auto,
    /// Never use it (fall back to the per-row tap loop).
    Off,
    /// Use it with this pixel-strip length (rounded to a divisor of P·Q).
    Strip(usize),
}

/// Convolution layer shape + blocking.
#[derive(Debug, Clone, Copy)]
pub struct ConvConfig {
    pub n: usize,
    pub c: usize,
    pub k: usize,
    pub h: usize,
    pub w: usize,
    pub r: usize,
    pub s: usize,
    pub stride: usize,
    pub pad: usize,
    /// Feature-block factors (divide C and K) and output-pixel strip.
    pub bc: usize,
    pub bk: usize,
    pub bq: usize,
    /// Spatial-collapse mode for eligible 1×1 layers (autotuned axis).
    pub flat: FlatSpatial,
    /// Forward loop order / thread partition override; `None` = the
    /// paper's shape-driven heuristic ([`Partition2d::auto`]).
    pub par_strategy: Option<Strategy>,
    pub act: Option<Act>,
    pub nthreads: usize,
}

impl ConvConfig {
    pub fn new(
        n: usize,
        c: usize,
        k: usize,
        h: usize,
        w: usize,
        r: usize,
        s: usize,
        stride: usize,
        pad: usize,
    ) -> ConvConfig {
        let q = (w + 2 * pad - s) / stride + 1;
        ConvConfig {
            n,
            c,
            k,
            h,
            w,
            r,
            s,
            stride,
            pad,
            bc: largest_divisor_le(c, 64),
            bk: largest_divisor_le(k, 64),
            bq: largest_divisor_le(q, 28),
            flat: FlatSpatial::Auto,
            par_strategy: None,
            act: None,
            nthreads: 1,
        }
    }

    /// Set the blocking factors. Each factor must be ≥ 1 and is rounded
    /// *down* to the largest divisor of its dimension (`bc`|C, `bk`|K,
    /// `bq`|Q) — a non-divisor block size would silently mis-shape every
    /// downstream packed tensor, so it is never accepted verbatim.
    pub fn with_blocking(mut self, bc: usize, bk: usize, bq: usize) -> ConvConfig {
        assert!(bc >= 1 && bk >= 1 && bq >= 1, "block sizes must be >= 1");
        self.bc = largest_divisor_le(self.c, bc);
        self.bk = largest_divisor_le(self.k, bk);
        self.bq = largest_divisor_le(self.q(), bq);
        self.validate();
        self
    }

    /// Override the spatial-collapse mode (autotuned axis; see
    /// [`FlatSpatial`]).
    pub fn with_flat(mut self, flat: FlatSpatial) -> ConvConfig {
        self.flat = flat;
        self
    }

    /// Pin the forward loop order / thread partition strategy instead of
    /// the shape-driven heuristic (autotuned axis).
    pub fn with_loop_order(mut self, s: Strategy) -> ConvConfig {
        self.par_strategy = Some(s);
        self
    }

    /// Forward-pass work partition honouring [`Self::par_strategy`].
    fn partition(&self, rows: usize, cols: usize, big_weights: bool) -> Partition2d {
        match self.par_strategy {
            Some(s) => Partition2d::new(rows, cols, self.nthreads, s),
            None => Partition2d::auto(rows, cols, self.nthreads, big_weights),
        }
    }

    pub fn with_threads(mut self, t: usize) -> ConvConfig {
        self.nthreads = t;
        self
    }

    pub fn with_act(mut self, act: Act) -> ConvConfig {
        self.act = Some(act);
        self
    }

    fn validate(&self) {
        assert_eq!(self.c % self.bc, 0, "bc must divide C");
        assert_eq!(self.k % self.bk, 0, "bk must divide K");
        assert_eq!(self.q() % self.bq, 0, "bq must divide Q");
        assert!(self.stride >= 1);
        assert!(self.h + 2 * self.pad >= self.r && self.w + 2 * self.pad >= self.s);
    }

    /// Output spatial dims.
    pub fn p(&self) -> usize {
        (self.h + 2 * self.pad - self.r) / self.stride + 1
    }
    pub fn q(&self) -> usize {
        (self.w + 2 * self.pad - self.s) / self.stride + 1
    }
    /// Padded input spatial dims.
    pub fn hp(&self) -> usize {
        self.h + 2 * self.pad
    }
    pub fn wp(&self) -> usize {
        self.w + 2 * self.pad
    }
    pub fn cb_ct(&self) -> usize {
        self.c / self.bc
    }
    pub fn kb_ct(&self) -> usize {
        self.k / self.bk
    }

    /// GEMM flops of one forward pass (= bwd-data = upd flop count).
    pub fn flops(&self) -> f64 {
        2.0 * self.n as f64
            * self.k as f64
            * self.c as f64
            * self.r as f64
            * self.s as f64
            * self.p() as f64
            * self.q() as f64
    }

    /// Sizes of the packed buffers.
    pub fn input_len(&self) -> usize {
        self.n * self.cb_ct() * self.hp() * self.wp() * self.bc
    }
    pub fn output_len(&self) -> usize {
        self.n * self.kb_ct() * self.p() * self.q() * self.bk
    }
    pub fn weights_len(&self) -> usize {
        self.k * self.c * self.r * self.s
    }
}

/// Timing breakdown (GEMM vs reformat) for the paper's accounting.
#[derive(Debug, Clone, Copy, Default)]
pub struct ConvBreakdown {
    pub gemm_secs: f64,
    pub reformat_secs: f64,
}

/// Packed conv weights + bias split out of execution state and shared via
/// [`Arc`]: one packed copy backs any number of [`ConvPrimitive`]
/// execution plans (the serving subsystem builds one plan per batch
/// bucket over a single weight allocation). The packed layout
/// `[Kb][Cb][R][S][bc][bk]` depends only on the filter shape and the
/// feature blocking `(bk, bc)` — never on the mini-batch — so every plan
/// whose blocking matches executes against the same buffer.
#[derive(Clone)]
pub struct ConvSharedWeights {
    pub k: usize,
    pub c: usize,
    pub r: usize,
    pub s: usize,
    pub bk: usize,
    pub bc: usize,
    w: Arc<Vec<f32>>,    // packed [Kb][Cb][R][S][bc][bk]
    bias: Arc<Vec<f32>>, // [K]
}

impl ConvSharedWeights {
    /// Pack plain `[K][C][R][S]` weights + `[K]` bias once for the
    /// blocking of `cfg`. Clones bump the [`Arc`]s — no repack, no copy.
    pub fn pack(cfg: &ConvConfig, w_plain: &[f32], bias: &[f32]) -> ConvSharedWeights {
        assert_eq!(w_plain.len(), cfg.weights_len());
        assert_eq!(bias.len(), cfg.k);
        let packed = layout::pack_conv_weights(
            w_plain, cfg.k, cfg.c, cfg.r, cfg.s, cfg.bk, cfg.bc,
        );
        ConvSharedWeights {
            k: cfg.k,
            c: cfg.c,
            r: cfg.r,
            s: cfg.s,
            bk: cfg.bk,
            bc: cfg.bc,
            w: Arc::new(packed),
            bias: Arc::new(bias.to_vec()),
        }
    }

    /// Wrap already-packed buffers (e.g. lifted out of a trained model).
    pub fn from_packed(cfg: &ConvConfig, w: Vec<f32>, bias: Vec<f32>) -> ConvSharedWeights {
        assert_eq!(w.len(), cfg.weights_len());
        assert_eq!(bias.len(), cfg.k);
        ConvSharedWeights {
            k: cfg.k,
            c: cfg.c,
            r: cfg.r,
            s: cfg.s,
            bk: cfg.bk,
            bc: cfg.bc,
            w: Arc::new(w),
            bias: Arc::new(bias),
        }
    }

    pub fn w(&self) -> &[f32] {
        &self.w
    }

    pub fn bias(&self) -> &[f32] {
        &self.bias
    }

    /// Unpack to the canonical plain layouts (`[K][C][R][S]` row-major
    /// weights, `[K]` bias) — the weight-extraction path the
    /// model-artifact subsystem uses. Packing is a pure permutation, so
    /// `pack(cfg, to_plain())` reproduces the packed buffer bit for bit.
    pub fn to_plain(&self) -> (Vec<f32>, Vec<f32>) {
        (
            layout::unpack_conv_weights(&self.w, self.k, self.c, self.r, self.s, self.bk, self.bc),
            self.bias.to_vec(),
        )
    }

    /// Can an execution plan with this config run against these weights?
    /// Filter shape and feature blocking must agree; the mini-batch (and
    /// pixel strip `bq`) are free per plan.
    pub fn matches(&self, cfg: &ConvConfig) -> bool {
        self.k == cfg.k
            && self.c == cfg.c
            && self.r == cfg.r
            && self.s == cfg.s
            && self.bk == cfg.bk
            && self.bc == cfg.bc
    }

    /// Stable identity of the underlying packed-weight allocation; two
    /// clones share it (see [`crate::primitives::fc::FcSharedWeights::alloc_id`]).
    pub fn alloc_id(&self) -> usize {
        Arc::as_ptr(&self.w) as usize
    }
}

/// The BRGEMM-based convolution primitive.
pub struct ConvPrimitive {
    pub cfg: ConvConfig,
    fwd_kernel: BrgemmKernel,
    /// Flattened-spatial forward kernel for 1×1/stride-1 layers (treats
    /// P×Q as one dimension — the paper's "spatial dimensions collapse"
    /// optimisation). `None` when not applicable.
    fwd_flat: Option<(BrgemmKernel, usize)>,
    upd_kernel: BrgemmKernel,
    /// Profiler slot — `None` (one branch per pass) unless a
    /// [`crate::telemetry`] profiler was installed at construction time.
    tele: Option<Arc<PrimSlot>>,
}

impl ConvPrimitive {
    pub fn new(cfg: ConvConfig) -> ConvPrimitive {
        let mut prim = ConvPrimitive::new_internal(cfg);
        prim.tele = telemetry::register(
            "conv",
            format!(
                "n{} c{} k{} {}x{} f{}x{}/{}",
                cfg.n, cfg.c, cfg.k, cfg.h, cfg.w, cfg.r, cfg.s, cfg.stride
            ),
        );
        prim
    }

    /// Construction without profiler registration — for internal helper
    /// primitives (the backward pass builds a dual-convolution plan per
    /// call; its kernel work is charged to the *outer* primitive's slot,
    /// so it must not register its own).
    fn new_internal(cfg: ConvConfig) -> ConvPrimitive {
        cfg.validate();
        let fwd = BrgemmKernel::new(BrgemmDesc {
            m: cfg.bq,
            n: cfg.bk,
            k: cfg.bc,
            lda: cfg.stride * cfg.bc,
            ldb: cfg.bk,
            ldc: cfg.bk,
            a_kstride: 1,
            alpha: 1.0,
            beta: 0.0,
        });
        // Spatial collapse: legal when the input walk is contiguous across
        // row ends, i.e. 1×1 taps, unit stride, no padding gap. The mode
        // and strip length are an autotuned axis ([`FlatSpatial`]).
        let flat_legal = cfg.r == 1 && cfg.s == 1 && cfg.stride == 1 && cfg.pad == 0;
        let fwd_flat = if flat_legal && cfg.flat != FlatSpatial::Off {
            let pq = cfg.p() * cfg.q();
            let bq = match cfg.flat {
                FlatSpatial::Strip(s) => largest_divisor_le(pq, s.max(1)),
                _ => largest_divisor_le(pq, 64),
            };
            let kern = BrgemmKernel::new(BrgemmDesc {
                m: bq,
                n: cfg.bk,
                k: cfg.bc,
                lda: cfg.bc,
                ldb: cfg.bk,
                ldc: cfg.bk,
                a_kstride: 1,
                alpha: 1.0,
                beta: 0.0,
            });
            Some((kern, bq))
        } else {
            None
        };
        // UPD: dW_blk[bc×bk] = Σ_{n,oj} ITᵀ rows × dO rows; k dim = Q pixels,
        // read with a_kstride = stride.
        let upd = BrgemmKernel::new(BrgemmDesc {
            m: cfg.bc,
            n: cfg.bk,
            k: cfg.q(),
            lda: cfg.wp(),
            ldb: cfg.bk,
            ldc: cfg.bk,
            a_kstride: cfg.stride,
            alpha: 1.0,
            beta: 1.0,
        });
        ConvPrimitive { cfg, fwd_kernel: fwd, fwd_flat, upd_kernel: upd, tele: None }
    }

    /// Tensor bytes one pass touches (input + output + weights, f32) —
    /// the roofline's memory term for this shape.
    fn bytes_moved(&self) -> u64 {
        let c = &self.cfg;
        4 * (c.input_len() + c.output_len() + c.weights_len()) as u64
    }

    /// Exact BRGEMM invocation count of one [`Self::forward`] call — a
    /// pure function of the config, so the backward pass (which reuses the
    /// forward loop nest through an internal dual primitive) can charge
    /// the right count to its own slot.
    fn fwd_brgemm_calls(&self) -> u64 {
        let cfg = &self.cfg;
        let kb = cfg.kb_ct();
        match &self.fwd_flat {
            // Flat path: one call per fbq-pixel strip (fbq divides P·Q).
            Some((_, fbq)) => (cfg.n * kb * (cfg.p() * cfg.q() / fbq)) as u64,
            // General path: one call per output row × bq-pixel strip.
            None => (cfg.n * kb * cfg.p() * (cfg.q() / cfg.bq)) as u64,
        }
    }

    /// Like [`ConvPrimitive::new`], but first consults the persistent
    /// tuning cache (shape + ISA + thread count key) and, on a hit, applies
    /// the cached winning blocking / flat-strip / loop-order. On a miss the
    /// config is used as-is — populate the cache with the `tune` CLI
    /// subcommand or [`crate::autotune::tuner::tune_conv_cached`].
    pub fn tuned(cfg: ConvConfig) -> ConvPrimitive {
        ConvPrimitive::new(crate::autotune::tuned_conv_config(cfg))
    }

    /// Forward against [`ConvSharedWeights`]: asserts the blocking
    /// matches, then runs [`Self::forward`] with the shared buffers (bias
    /// always applied — serving layers carry one). This is the serving hot
    /// path — many batch-bucket plans, one weight copy.
    pub fn forward_shared(&self, input: &[f32], w: &ConvSharedWeights, out: &mut [f32]) {
        assert!(
            w.matches(&self.cfg),
            "shared weights (k{} c{} {}x{} bk{} bc{}) do not match plan (k{} c{} {}x{} bk{} bc{})",
            w.k, w.c, w.r, w.s, w.bk, w.bc,
            self.cfg.k, self.cfg.c, self.cfg.r, self.cfg.s, self.cfg.bk, self.cfg.bc
        );
        self.forward(input, w.w(), Some(w.bias()), out);
    }

    /// Forward (Algorithm 4): `out = conv(input, weights) [+bias, act]`.
    /// `input` is packed+padded, `weights` packed, `out` packed (unpadded).
    pub fn forward(&self, input: &[f32], weights: &[f32], bias: Option<&[f32]>, out: &mut [f32]) {
        let cfg = &self.cfg;
        assert_eq!(input.len(), cfg.input_len());
        assert_eq!(weights.len(), cfg.weights_len());
        assert_eq!(out.len(), cfg.output_len());
        if let Some(b) = bias {
            assert_eq!(b.len(), cfg.k);
        }
        let t0 = self.tele.as_ref().map(|_| Instant::now());
        let (cb, kb) = (cfg.cb_ct(), cfg.kb_ct());
        let (p, q) = (cfg.p(), cfg.q());
        let (hp, wp) = (cfg.hp(), cfg.wp());
        let batch = cfg.r * cfg.s * cb;
        let wtap = cfg.bc * cfg.bk; // one packed weight block
        let shared = &SharedMut::new(out);
        let part = cfg.partition(cfg.n, kb, cfg.weights_len() > 1 << 20);
        let epi = match (bias, cfg.act) {
            (Some(_), Some(a)) => Epilogue::BiasAct(a),
            (Some(_), None) => Epilogue::BiasAct(Act::Identity),
            (None, Some(a)) => Epilogue::Act(a),
            (None, None) => Epilogue::None,
        };

        if let Some((flat_kern, fbq)) = &self.fwd_flat {
            // 1×1/s1/p0: collapse P×Q; input pixel index = output pixel index.
            let pq = p * q;
            let flat_kern = flat_kern.clone().with_epilogue(epi);
            parallel_region(cfg.nthreads, |tid| {
                let mut a_offs = vec![0usize; cb];
                let mut b_offs = vec![0usize; cb];
                for (n, ikb) in part.tasks(tid) {
                    let bias_blk = bias.map(|b| &b[ikb * cfg.bk..(ikb + 1) * cfg.bk]);
                    for op in (0..pq).step_by(*fbq) {
                        for icb in 0..cb {
                            a_offs[icb] = ((n * cb + icb) * hp * wp + op) * cfg.bc;
                            b_offs[icb] = (ikb * cb + icb) * wtap;
                        }
                        let o_off = ((n * kb + ikb) * pq + op) * cfg.bk;
                        let ob = unsafe { shared.slice(o_off, fbq * cfg.bk) };
                        flat_kern.execute_offs(input, &a_offs, weights, &b_offs, ob, bias_blk);
                    }
                }
            });
            if let (Some(slot), Some(t0)) = (self.tele.as_ref(), t0) {
                slot.record(
                    Pass::Fwd,
                    self.fwd_brgemm_calls(),
                    cfg.flops(),
                    self.bytes_moved(),
                    t0.elapsed(),
                );
            }
            return;
        }

        let kern = self.fwd_kernel.clone().with_epilogue(epi);
        parallel_region(cfg.nthreads, |tid| {
            let mut a_offs = vec![0usize; batch];
            let mut b_offs = vec![0usize; batch];
            for (n, ikb) in part.tasks(tid) {
                let bias_blk = bias.map(|b| &b[ikb * cfg.bk..(ikb + 1) * cfg.bk]);
                for oj in 0..p {
                    let ij = cfg.stride * oj;
                    for oib in 0..q / cfg.bq {
                        let oi = oib * cfg.bq;
                        let ii = cfg.stride * oi;
                        // Gather the batch: every (icb, r, s) tap.
                        let mut bi = 0;
                        for icb in 0..cb {
                            for rr in 0..cfg.r {
                                for ss in 0..cfg.s {
                                    a_offs[bi] = (((n * cb + icb) * hp + (ij + rr)) * wp
                                        + (ii + ss))
                                        * cfg.bc;
                                    b_offs[bi] =
                                        ((((ikb * cb) + icb) * cfg.r + rr) * cfg.s + ss) * wtap;
                                    bi += 1;
                                }
                            }
                        }
                        let o_off = (((n * kb + ikb) * p + oj) * q + oi) * cfg.bk;
                        let ob = unsafe { shared.slice(o_off, cfg.bq * cfg.bk) };
                        kern.execute_offs(input, &a_offs, weights, &b_offs, ob, bias_blk);
                    }
                }
            }
        });
        if let (Some(slot), Some(t0)) = (self.tele.as_ref(), t0) {
            slot.record(
                Pass::Fwd,
                self.fwd_brgemm_calls(),
                cfg.flops(),
                self.bytes_moved(),
                t0.elapsed(),
            );
        }
    }

    /// Dual-weight reformat for [`Self::backward_data_pre`]: (C↔K)-
    /// transposed, 180°-rotated packed weights. Computed once per weight
    /// version and amortised across backward calls (the same amortisation
    /// the paper applies to the LSTM weight transpose).
    pub fn dual_weights(&self, weights: &[f32]) -> Vec<f32> {
        let cfg = &self.cfg;
        layout::dual_conv_weights(weights, cfg.k, cfg.c, cfg.r, cfg.s, cfg.bk, cfg.bc)
    }

    /// Backward by data ("dual convolution") with the dual reformat done
    /// (and charged) per call — convenience wrapper over
    /// [`Self::backward_data_pre`].
    pub fn backward_data(&self, d_out: &[f32], weights: &[f32]) -> (Vec<f32>, ConvBreakdown) {
        let t0 = Instant::now();
        let dual = self.dual_weights(weights);
        let reformat = t0.elapsed().as_secs_f64();
        let (di, mut bd) = self.backward_data_pre(d_out, &dual);
        bd.reformat_secs += reformat;
        (di, bd)
    }

    /// Backward by data given precomputed [`Self::dual_weights`]. Returns
    /// the packed **padded** input-gradient buffer (same geometry as the
    /// forward input), so `layout::unpack_conv_act(.., cfg.pad, ..)`
    /// recovers plain dI.
    pub fn backward_data_pre(&self, d_out: &[f32], dual: &[f32]) -> (Vec<f32>, ConvBreakdown) {
        let cfg = &self.cfg;
        assert_eq!(d_out.len(), cfg.output_len());
        assert_eq!(dual.len(), cfg.weights_len());
        let tele0 = self.tele.as_ref().map(|_| Instant::now());
        let mut bd = ConvBreakdown::default();

        if cfg.stride == 1 {
            // dIpad = conv_{s1}(pad_{R-1}(dO), dual) — run the forward
            // primitive with roles swapped.
            let t0 = Instant::now();
            let (p, q) = (cfg.p(), cfg.q());
            // Re-pad dO by (R-1, S-1) directly in blocked form (perf-pass
            // iteration 2: the unpack→repack round trip dominated BWD;
            // iteration 3: 1×1 taps need no padding at all — zero copies).
            let dop_owned;
            let dop: &[f32] = if cfg.r == 1 && cfg.s == 1 {
                d_out
            } else {
                dop_owned = layout::repad_blocked(
                    d_out, cfg.n, cfg.kb_ct(), p, q, cfg.bk, cfg.r - 1, cfg.s - 1,
                );
                &dop_owned
            };
            bd.reformat_secs += t0.elapsed().as_secs_f64();
            let t0 = Instant::now();
            let dual_cfg = ConvConfig::new(
                cfg.n,
                cfg.k,
                cfg.c,
                p + 2 * (cfg.r - 1) - 2 * (cfg.r - 1), // logical H of dO = P
                q,
                cfg.r,
                cfg.s,
                1,
                cfg.r - 1,
            )
            .with_blocking(cfg.bk, cfg.bc, largest_divisor_le(cfg.wp(), 64))
            .with_threads(cfg.nthreads);
            // Sanity: dual output spatial dims = padded input dims.
            debug_assert_eq!(dual_cfg.p(), cfg.hp());
            debug_assert_eq!(dual_cfg.q(), cfg.wp());
            // new_internal: the dual plan's kernel work is charged to THIS
            // primitive's slot — a registering constructor here would leak
            // one fresh slot per backward call.
            let prim = ConvPrimitive::new_internal(dual_cfg);
            let mut di = vec![0.0f32; dual_cfg.output_len()];
            prim.forward(dop, dual, None, &mut di);
            bd.gemm_secs += t0.elapsed().as_secs_f64();
            if let (Some(slot), Some(tele0)) = (self.tele.as_ref(), tele0) {
                slot.record(
                    Pass::Bwd,
                    prim.fwd_brgemm_calls(),
                    cfg.flops(),
                    self.bytes_moved(),
                    tele0.elapsed(),
                );
            }
            // di is [N][Cb][Hp][Wp][bc] — exactly the padded input geometry.
            return (di, bd);
        }

        if cfg.r == 1 && cfg.s == 1 && cfg.pad == 0 {
            // Strided 1×1: dI is non-zero only at stride-aligned pixels.
            let t0 = Instant::now();
            let (cb, kb) = (cfg.cb_ct(), cfg.kb_ct());
            let (p, q) = (cfg.p(), cfg.q());
            let (hp, wp) = (cfg.hp(), cfg.wp());
            let mut di = vec![0.0f32; cfg.input_len()];
            let kern = BrgemmKernel::new(BrgemmDesc {
                m: cfg.bq,
                n: cfg.bc,
                k: cfg.bk,
                lda: cfg.bk,
                ldb: cfg.bc,
                ldc: cfg.stride * cfg.bc,
                a_kstride: 1,
                alpha: 1.0,
                beta: 0.0,
            });
            let wtap = cfg.bc * cfg.bk;
            let shared = &SharedMut::new(&mut di);
            let part = Partition2d::auto(cfg.n, cb, cfg.nthreads, false);
            parallel_region(cfg.nthreads, |tid| {
                let mut a_offs = vec![0usize; kb];
                let mut b_offs = vec![0usize; kb];
                for (n, icb) in part.tasks(tid) {
                    for oj in 0..p {
                        for oib in 0..q / cfg.bq {
                            let oi = oib * cfg.bq;
                            for ikb in 0..kb {
                                a_offs[ikb] =
                                    (((n * kb + ikb) * p + oj) * q + oi) * cfg.bk;
                                // dual layout [Cb][Kb][bk][bc]
                                b_offs[ikb] = (icb * kb + ikb) * wtap;
                            }
                            let off = (((n * cb + icb) * hp + cfg.stride * oj) * wp
                                + cfg.stride * oi)
                                * cfg.bc;
                            let len = (cfg.bq - 1) * cfg.stride * cfg.bc + cfg.bc;
                            let out = unsafe { shared.slice(off, len) };
                            kern.execute_offs(d_out, &a_offs, &dual, &b_offs, out, None);
                        }
                    }
                }
            });
            bd.gemm_secs += t0.elapsed().as_secs_f64();
            if let (Some(slot), Some(tele0)) = (self.tele.as_ref(), tele0) {
                // One BRGEMM call per (n, icb, oj, oi-strip).
                let calls = (cfg.n * cb * p * (q / cfg.bq)) as u64;
                slot.record(Pass::Bwd, calls, cfg.flops(), self.bytes_moved(), tele0.elapsed());
            }
            return (di, bd);
        }

        // General strided case (ResNet uses it only for the stem 7×7/s2):
        // naive scatter, documented fallback.
        let t0 = Instant::now();
        let plain_dy =
            layout::unpack_conv_act(d_out, cfg.n, cfg.k, cfg.p(), cfg.q(), cfg.bk, 0, 0);
        // Recover the forward weights from the dual (dual ∘ dual = id).
        let fwd_packed =
            layout::dual_conv_weights(dual, cfg.c, cfg.k, cfg.r, cfg.s, cfg.bc, cfg.bk);
        let plain_w =
            layout::unpack_conv_weights(&fwd_packed, cfg.k, cfg.c, cfg.r, cfg.s, cfg.bk, cfg.bc);
        let dx = crate::primitives::naive::conv_bwd_data(
            cfg.n, cfg.c, cfg.k, cfg.h, cfg.w, cfg.r, cfg.s, cfg.stride, cfg.pad, &plain_dy,
            &plain_w,
        );
        let di = layout::pack_conv_act(&dx, cfg.n, cfg.c, cfg.h, cfg.w, cfg.bc, cfg.pad, cfg.pad);
        bd.gemm_secs += t0.elapsed().as_secs_f64();
        if let (Some(slot), Some(tele0)) = (self.tele.as_ref(), tele0) {
            // Naive fallback: the flops happen, but no BRGEMM is issued.
            slot.record(Pass::Bwd, 0, cfg.flops(), self.bytes_moved(), tele0.elapsed());
        }
        (di, bd)
    }

    /// Weight + bias update: convenience wrapper running
    /// [`Self::update_weights`] and [`Self::update_bias`] — what a training
    /// step with a learnable per-channel bias needs. Passes that only
    /// consume dW (bias-free layers, the paper-exact Fig. 8 / Fig. 10b
    /// timings) call [`Self::update_weights`] directly and skip the
    /// O(N·K·P·Q) bias reduction entirely.
    pub fn update(&self, input: &[f32], d_out: &[f32]) -> (Vec<f32>, Vec<f32>, ConvBreakdown) {
        let (dw, bd) = self.update_weights(input, d_out);
        let db = self.update_bias(d_out);
        (dw, db, bd)
    }

    /// Weight update: `dW = Σ_{n,oj,oi} I ⊗ dO` reduced in one BRGEMM chain
    /// per weight block; activations are consumed via the per-row channel
    /// transpose (the pass's reformat cost). This is the paper's UPD pass
    /// exactly — no bias gradient (see [`Self::update_bias`]).
    pub fn update_weights(&self, input: &[f32], d_out: &[f32]) -> (Vec<f32>, ConvBreakdown) {
        let cfg = &self.cfg;
        assert_eq!(input.len(), cfg.input_len());
        assert_eq!(d_out.len(), cfg.output_len());
        let tele0 = self.tele.as_ref().map(|_| Instant::now());
        let mut bd = ConvBreakdown::default();
        let (cb, kb) = (cfg.cb_ct(), cfg.kb_ct());
        let (p, q) = (cfg.p(), cfg.q());
        let (hp, wp) = (cfg.hp(), cfg.wp());
        let t0 = Instant::now();
        let it = layout::transpose_act_rows(input, cfg.n, cb, hp, wp, cfg.bc);
        bd.reformat_secs += t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let mut dw = vec![0.0f32; cfg.weights_len()];
        let wtap = cfg.bc * cfg.bk;
        let shared = &SharedMut::new(&mut dw);
        // Task space: (Kb×Cb) blocks × (R·S) taps, flattened.
        let part = Partition2d::new(kb * cb, cfg.r * cfg.s, cfg.nthreads, crate::primitives::partition::Strategy::Flat);
        parallel_region(cfg.nthreads, |tid| {
            let batch = cfg.n * p;
            let mut a_offs = vec![0usize; batch];
            let mut b_offs = vec![0usize; batch];
            for (kc, rs) in part.tasks(tid) {
                let (ikb, icb) = (kc / cb, kc % cb);
                let (rr, ss) = (rs / cfg.s, rs % cfg.s);
                let mut bi = 0;
                for n in 0..cfg.n {
                    for oj in 0..p {
                        let ij = cfg.stride * oj + rr;
                        // IT row [n][icb][ij][0][ss]
                        a_offs[bi] = (((n * cb + icb) * hp + ij) * wp) * cfg.bc + ss;
                        b_offs[bi] = (((n * kb + ikb) * p + oj) * q) * cfg.bk;
                        bi += 1;
                    }
                }
                let off = ((((ikb * cb) + icb) * cfg.r + rr) * cfg.s + ss) * wtap;
                let out = unsafe { shared.slice(off, wtap) };
                out.fill(0.0); // β=1 kernel accumulates over the chain
                self.upd_kernel.execute_offs(&it, &a_offs, d_out, &b_offs, out, None);
            }
        });
        bd.gemm_secs += t0.elapsed().as_secs_f64();
        if let (Some(slot), Some(tele0)) = (self.tele.as_ref(), tele0) {
            // One BRGEMM call per (Kb × Cb) block × (R·S) tap; the bias
            // reduction ([`Self::update_bias`]) issues none.
            let calls = (kb * cb * cfg.r * cfg.s) as u64;
            slot.record(Pass::Upd, calls, cfg.flops(), self.bytes_moved(), tele0.elapsed());
        }
        (dw, bd)
    }

    /// Bias gradient: `db[k] = Σ_{n,p,q} dO` — the reduction implied by the
    /// per-channel bias that [`Self::forward`] consumes. The blocked layout
    /// puts channel k at `[kb][..][k % bk]`, so the db index `ikb·bk + j`
    /// is the plain channel index. Parallelism is *below* channel-block
    /// granularity: the K channels are statically chunked across threads,
    /// so kb = 1 layers (e.g. the 64-channel stage-1 stack) scale instead
    /// of running the whole sweep on one thread. Per channel the
    /// accumulation order (mini-batch, then pixels) is unchanged, so the
    /// result is bit-identical at every thread count.
    pub fn update_bias(&self, d_out: &[f32]) -> Vec<f32> {
        let cfg = &self.cfg;
        assert_eq!(d_out.len(), cfg.output_len());
        let kb = cfg.kb_ct();
        let (p, q) = (cfg.p(), cfg.q());
        let mut db = vec![0.0f32; cfg.k];
        parallel_chunks_mut(cfg.nthreads, &mut db, |_tid, offset, chunk| {
            for (jj, slot) in chunk.iter_mut().enumerate() {
                let ch = offset + jj;
                let (ikb, lane) = (ch / cfg.bk, ch % cfg.bk);
                let mut acc = 0.0f32;
                for n in 0..cfg.n {
                    let base = (n * kb + ikb) * p * q * cfg.bk + lane;
                    for pix in 0..p * q {
                        acc += d_out[base + pix * cfg.bk];
                    }
                }
                *slot = acc;
            }
        });
        db
    }
}

// ---------------------------------------------------------------------------
// Figure-1 baselines
// ---------------------------------------------------------------------------

/// Baseline (Fig. 1 "gemm-conv"): Algorithm-3 loop nest with one *small
/// GEMM per (r, s, cb) tap* — identical blocking/layout to the BRGEMM path
/// but no batch reduction, so the output strip is loaded/stored from memory
/// `R·S·Cb` times (β = 1 accumulation).
pub fn conv_forward_small_gemm(cfg: &ConvConfig, input: &[f32], weights: &[f32], out: &mut [f32]) {
    let (cb, kb) = (cfg.cb_ct(), cfg.kb_ct());
    let (p, q) = (cfg.p(), cfg.q());
    let (hp, wp) = (cfg.hp(), cfg.wp());
    let wtap = cfg.bc * cfg.bk;
    out.fill(0.0);
    let kern = BrgemmKernel::new(BrgemmDesc {
        m: cfg.bq,
        n: cfg.bk,
        k: cfg.bc,
        lda: cfg.stride * cfg.bc,
        ldb: cfg.bk,
        ldc: cfg.bk,
        a_kstride: 1,
        alpha: 1.0,
        beta: 1.0,
    });
    let shared = &SharedMut::new(out);
    let part = Partition2d::auto(cfg.n, kb, cfg.nthreads, false);
    parallel_region(cfg.nthreads, |tid| {
        for (n, ikb) in part.tasks(tid) {
            for icb in 0..cb {
                for oj in 0..p {
                    let ij = cfg.stride * oj;
                    for oib in 0..q / cfg.bq {
                        let oi = oib * cfg.bq;
                        let ii = cfg.stride * oi;
                        let o_off = (((n * kb + ikb) * p + oj) * q + oi) * cfg.bk;
                        let ob = unsafe { shared.slice(o_off, cfg.bq * cfg.bk) };
                        for rr in 0..cfg.r {
                            for ss in 0..cfg.s {
                                let a = (((n * cb + icb) * hp + (ij + rr)) * wp + (ii + ss))
                                    * cfg.bc;
                                let b = ((((ikb * cb) + icb) * cfg.r + rr) * cfg.s + ss) * wtap;
                                kern.execute_offs(input, &[a], weights, &[b], ob, None);
                            }
                        }
                    }
                }
            }
        }
    });
}

/// Baseline (Fig. 1 "im2col + GEMM"): per image, materialise the
/// `[C·R·S][P·Q]` column tensor, then one large GEMM
/// `O[K][P·Q] = W[K][C·R·S] · col`. Plain NCHW/KCRS layouts.
#[allow(clippy::too_many_arguments)]
pub fn conv_forward_im2col(
    cfg: &ConvConfig,
    x: &[f32],  // [N][C][H][W]
    w: &[f32],  // [K][C][R][S]
    y: &mut [f32], // [N][K][P][Q]
) {
    let (n, c, k) = (cfg.n, cfg.c, cfg.k);
    let (h, wd, r, s) = (cfg.h, cfg.w, cfg.r, cfg.s);
    let (p, q) = (cfg.p(), cfg.q());
    let crs = c * r * s;
    let pq = p * q;
    let mut col = vec![0.0f32; crs * pq];
    let gemm = Gemm::dense(k, pq, crs);
    for ni in 0..n {
        // im2col (the copy overhead the paper charges this approach with)
        for cc in 0..c {
            for rr in 0..r {
                for ss in 0..s {
                    let row = ((cc * r + rr) * s + ss) * pq;
                    for oj in 0..p {
                        for oi in 0..q {
                            let ij = (oj * cfg.stride + rr) as isize - cfg.pad as isize;
                            let ii = (oi * cfg.stride + ss) as isize - cfg.pad as isize;
                            col[row + oj * q + oi] =
                                if ij < 0 || ii < 0 || ij >= h as isize || ii >= wd as isize {
                                    0.0
                                } else {
                                    x[((ni * c + cc) * h + ij as usize) * wd + ii as usize]
                                };
                        }
                    }
                }
            }
        }
        gemm.execute(w, &col, &mut y[ni * k * pq..(ni + 1) * k * pq]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::primitives::naive;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn run_fwd(cfg: &ConvConfig, x: &[f32], w: &[f32]) -> Vec<f32> {
        let prim = ConvPrimitive::new(*cfg);
        let xp = layout::pack_conv_act(x, cfg.n, cfg.c, cfg.h, cfg.w, cfg.bc, cfg.pad, cfg.pad);
        let wp = layout::pack_conv_weights(w, cfg.k, cfg.c, cfg.r, cfg.s, cfg.bk, cfg.bc);
        let mut op = vec![0.0; cfg.output_len()];
        prim.forward(&xp, &wp, None, &mut op);
        layout::unpack_conv_act(&op, cfg.n, cfg.k, cfg.p(), cfg.q(), cfg.bk, 0, 0)
    }

    fn check_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
        assert_eq!(got.len(), want.len());
        for i in 0..got.len() {
            assert!(
                (got[i] - want[i]).abs() < tol,
                "{}: [{}] {} vs {}",
                what,
                i,
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn forward_matches_naive_various_shapes() {
        let cases = [
            // (n,c,k,h,w,r,s,str,pad)
            (1, 4, 8, 6, 6, 3, 3, 1, 1),
            (2, 8, 8, 5, 7, 1, 1, 1, 0),
            (1, 4, 4, 8, 8, 1, 1, 2, 0),
            (2, 2, 6, 9, 9, 3, 3, 2, 1),
            (1, 6, 4, 7, 7, 7, 7, 2, 3),
            (1, 3, 5, 6, 6, 2, 2, 1, 0),
        ];
        for &(n, c, k, h, w, r, s, st, pad) in &cases {
            let mut rng = Rng::new((n * c * k + h) as u64);
            let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
            let wt = rng.vec_f32(k * c * r * s, -0.5, 0.5);
            let cfg = ConvConfig::new(n, c, k, h, w, r, s, st, pad);
            let got = run_fwd(&cfg, &x, &wt);
            let want = naive::conv_fwd(n, c, k, h, w, r, s, st, pad, &x, &wt);
            check_close(&got, &want, 1e-3, &format!("fwd {:?}", (n, c, k, h, w, r, s, st, pad)));
        }
    }

    #[test]
    fn forward_multithreaded_and_fused_relu() {
        let (n, c, k, h, w) = (2, 8, 16, 6, 6);
        let mut rng = Rng::new(3);
        let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
        let wt = rng.vec_f32(k * c * 9, -0.5, 0.5);
        let bias = rng.vec_f32(k, -0.1, 0.1);
        let cfg = ConvConfig::new(n, c, k, h, w, 3, 3, 1, 1).with_threads(3).with_act(Act::Relu);
        let prim = ConvPrimitive::new(cfg);
        let xp = layout::pack_conv_act(&x, n, c, h, w, cfg.bc, 1, 1);
        let wp = layout::pack_conv_weights(&wt, k, c, 3, 3, cfg.bk, cfg.bc);
        let mut op = vec![0.0; cfg.output_len()];
        prim.forward(&xp, &wp, Some(&bias), &mut op);
        let got = layout::unpack_conv_act(&op, n, k, cfg.p(), cfg.q(), cfg.bk, 0, 0);
        let plain = naive::conv_fwd(n, c, k, h, w, 3, 3, 1, 1, &x, &wt);
        let want: Vec<f32> = plain
            .iter()
            .enumerate()
            .map(|(i, v)| {
                let kk = (i / (cfg.p() * cfg.q())) % k;
                (v + bias[kk]).max(0.0)
            })
            .collect();
        check_close(&got, &want, 1e-3, "fused bias+relu");
    }

    #[test]
    fn backward_data_stride1() {
        let (n, c, k, h, w, r, s) = (1, 4, 6, 5, 5, 3, 3);
        let mut rng = Rng::new(8);
        let wt = rng.vec_f32(k * c * r * s, -0.5, 0.5);
        let cfg = ConvConfig::new(n, c, k, h, w, r, s, 1, 1);
        let dy = rng.vec_f32(n * k * cfg.p() * cfg.q(), -1.0, 1.0);
        let prim = ConvPrimitive::new(cfg);
        let wp = layout::pack_conv_weights(&wt, k, c, r, s, cfg.bk, cfg.bc);
        let dyp = layout::pack_conv_act(&dy, n, k, cfg.p(), cfg.q(), cfg.bk, 0, 0);
        let (dip, _) = prim.backward_data(&dyp, &wp);
        let di = layout::unpack_conv_act(&dip, n, c, h, w, cfg.bc, cfg.pad, cfg.pad);
        let want = naive::conv_bwd_data(n, c, k, h, w, r, s, 1, 1, &dy, &wt);
        check_close(&di, &want, 1e-3, "bwd s1");
    }

    #[test]
    fn backward_data_strided_1x1() {
        let (n, c, k, h, w) = (2, 4, 8, 8, 8);
        let mut rng = Rng::new(9);
        let wt = rng.vec_f32(k * c, -0.5, 0.5);
        let cfg = ConvConfig::new(n, c, k, h, w, 1, 1, 2, 0);
        let dy = rng.vec_f32(n * k * cfg.p() * cfg.q(), -1.0, 1.0);
        let prim = ConvPrimitive::new(cfg);
        let wp = layout::pack_conv_weights(&wt, k, c, 1, 1, cfg.bk, cfg.bc);
        let dyp = layout::pack_conv_act(&dy, n, k, cfg.p(), cfg.q(), cfg.bk, 0, 0);
        let (dip, _) = prim.backward_data(&dyp, &wp);
        let di = layout::unpack_conv_act(&dip, n, c, h, w, cfg.bc, 0, 0);
        let want = naive::conv_bwd_data(n, c, k, h, w, 1, 1, 2, 0, &dy, &wt);
        check_close(&di, &want, 1e-3, "bwd 1x1 s2");
    }

    #[test]
    fn backward_data_fallback_7x7s2() {
        let (n, c, k, h, w) = (1, 2, 4, 9, 9);
        let mut rng = Rng::new(10);
        let wt = rng.vec_f32(k * c * 49, -0.3, 0.3);
        let cfg = ConvConfig::new(n, c, k, h, w, 7, 7, 2, 3);
        let dy = rng.vec_f32(n * k * cfg.p() * cfg.q(), -1.0, 1.0);
        let prim = ConvPrimitive::new(cfg);
        let wp = layout::pack_conv_weights(&wt, k, c, 7, 7, cfg.bk, cfg.bc);
        let dyp = layout::pack_conv_act(&dy, n, k, cfg.p(), cfg.q(), cfg.bk, 0, 0);
        let (dip, _) = prim.backward_data(&dyp, &wp);
        let di = layout::unpack_conv_act(&dip, n, c, h, w, cfg.bc, cfg.pad, cfg.pad);
        let want = naive::conv_bwd_data(n, c, k, h, w, 7, 7, 2, 3, &dy, &wt);
        check_close(&di, &want, 1e-3, "bwd 7x7 s2 fallback");
    }

    #[test]
    fn update_matches_naive() {
        for &(n, c, k, h, w, r, s, st, pad) in &[
            (2, 4, 6, 6, 6, 3, 3, 1, 1),
            (1, 4, 4, 8, 8, 1, 1, 2, 0),
            (2, 2, 4, 7, 7, 3, 3, 2, 1),
        ] {
            let mut rng = Rng::new((h * w + k) as u64);
            let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
            let cfg = ConvConfig::new(n, c, k, h, w, r, s, st, pad);
            let dy = rng.vec_f32(n * k * cfg.p() * cfg.q(), -1.0, 1.0);
            let prim = ConvPrimitive::new(cfg);
            let xp = layout::pack_conv_act(&x, n, c, h, w, cfg.bc, pad, pad);
            let dyp = layout::pack_conv_act(&dy, n, k, cfg.p(), cfg.q(), cfg.bk, 0, 0);
            let (dwp, db, _) = prim.update(&xp, &dyp);
            let dw = layout::unpack_conv_weights(&dwp, k, c, r, s, cfg.bk, cfg.bc);
            let want = naive::conv_upd(n, c, k, h, w, r, s, st, pad, &x, &dy);
            check_close(&dw, &want, 1e-3, &format!("upd {:?}", (r, s, st, pad)));
            let db_want = naive::conv_bias_upd(n, k, cfg.p(), cfg.q(), &dy);
            check_close(&db, &db_want, 1e-3, &format!("upd db {:?}", (r, s, st, pad)));
        }
    }

    #[test]
    fn update_bias_gradient_nonzero_and_blocked_order() {
        // The headline bug: `forward` consumes a per-channel bias, so
        // `update` must produce its gradient. dY = 1 everywhere ⇒
        // db[k] = N·P·Q for every channel, regardless of blocking.
        let (n, c, k, h, w) = (2, 4, 8, 5, 5);
        let cfg = ConvConfig::new(n, c, k, h, w, 3, 3, 1, 1).with_blocking(2, 4, 5);
        let prim = ConvPrimitive::new(cfg);
        let xp = vec![0.5; cfg.input_len()];
        let dyp = vec![1.0; cfg.output_len()];
        let (_, db, _) = prim.update(&xp, &dyp);
        assert_eq!(db.len(), k);
        let want = (n * cfg.p() * cfg.q()) as f32;
        for (i, v) in db.iter().enumerate() {
            assert!((v - want).abs() < 1e-3, "db[{}] = {} want {}", i, v, want);
        }
    }

    #[test]
    fn update_split_and_parallel_bias_sweep() {
        // update = update_weights + update_bias, and the db sweep is
        // bit-identical at every thread count even below channel-block
        // granularity (kb = 1 here: one 8-wide block, 4 threads).
        let (n, c, k, h, w) = (2, 4, 8, 6, 6);
        let mut rng = Rng::new(33);
        let cfg = ConvConfig::new(n, c, k, h, w, 3, 3, 1, 1);
        assert_eq!(cfg.kb_ct(), 1, "test wants a sub-block-parallel case");
        let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
        let dy = rng.vec_f32(n * k * cfg.p() * cfg.q(), -1.0, 1.0);
        let xp = layout::pack_conv_act(&x, n, c, h, w, cfg.bc, 1, 1);
        let dyp = layout::pack_conv_act(&dy, n, k, cfg.p(), cfg.q(), cfg.bk, 0, 0);
        let prim = ConvPrimitive::new(cfg);
        let (dw_all, db_all, _) = prim.update(&xp, &dyp);
        let (dw_only, _) = prim.update_weights(&xp, &dyp);
        assert_eq!(dw_all, dw_only, "update_weights must be the dW half of update");
        assert_eq!(db_all, prim.update_bias(&dyp), "update_bias must be the db half");
        let prim4 = ConvPrimitive::new(cfg.with_threads(4));
        assert_eq!(prim4.update_bias(&dyp), db_all, "db bit-identical across thread counts");
    }

    #[test]
    fn baselines_match_naive() {
        let (n, c, k, h, w, r, s, st, pad) = (1, 4, 8, 6, 6, 3, 3, 1, 1);
        let mut rng = Rng::new(12);
        let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
        let wt = rng.vec_f32(k * c * r * s, -0.5, 0.5);
        let cfg = ConvConfig::new(n, c, k, h, w, r, s, st, pad);
        let want = naive::conv_fwd(n, c, k, h, w, r, s, st, pad, &x, &wt);
        // small-GEMM loop baseline (blocked layouts)
        let xp = layout::pack_conv_act(&x, n, c, h, w, cfg.bc, pad, pad);
        let wp = layout::pack_conv_weights(&wt, k, c, r, s, cfg.bk, cfg.bc);
        let mut op = vec![0.0; cfg.output_len()];
        conv_forward_small_gemm(&cfg, &xp, &wp, &mut op);
        let got = layout::unpack_conv_act(&op, n, k, cfg.p(), cfg.q(), cfg.bk, 0, 0);
        check_close(&got, &want, 1e-3, "small-gemm baseline");
        // im2col baseline (plain layouts)
        let mut y = vec![0.0; n * k * cfg.p() * cfg.q()];
        conv_forward_im2col(&cfg, &x, &wt, &mut y);
        check_close(&y, &want, 1e-3, "im2col baseline");
    }

    #[test]
    fn with_blocking_rounds_to_divisors() {
        let cfg = ConvConfig::new(1, 64, 96, 28, 28, 1, 1, 1, 0);
        // 48 ∤ 64 → rounds to 32; 100 > 96 → clamps to 96; 30 ∤ 28 → 28.
        let cfg = cfg.with_blocking(48, 100, 30);
        assert_eq!((cfg.bc, cfg.bk, cfg.bq), (32, 96, 28));
        // Exact divisors pass through untouched.
        let cfg = cfg.with_blocking(16, 32, 14);
        assert_eq!((cfg.bc, cfg.bk, cfg.bq), (16, 32, 14));
    }

    #[test]
    #[should_panic(expected = ">= 1")]
    fn with_blocking_rejects_zero() {
        ConvConfig::new(1, 8, 8, 8, 8, 1, 1, 1, 0).with_blocking(0, 8, 8);
    }

    #[test]
    fn flat_modes_agree_on_1x1() {
        let (n, c, k, h, w) = (2, 8, 8, 6, 6);
        let mut rng = Rng::new(21);
        let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
        let wt = rng.vec_f32(k * c, -0.5, 0.5);
        let base = ConvConfig::new(n, c, k, h, w, 1, 1, 1, 0);
        let want = run_fwd(&base, &x, &wt); // Auto (flat on)
        for cfg in [
            base.with_flat(FlatSpatial::Off),
            base.with_flat(FlatSpatial::Strip(12)),
            base.with_flat(FlatSpatial::Strip(5)), // 5 ∤ 36 → rounded
        ] {
            let got = run_fwd(&cfg, &x, &wt);
            check_close(&got, &want, 1e-4, &format!("flat mode {:?}", cfg.flat));
        }
    }

    #[test]
    fn loop_order_override_matches_auto() {
        let (n, c, k, h, w) = (3, 4, 8, 6, 6);
        let mut rng = Rng::new(22);
        let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
        let wt = rng.vec_f32(k * c * 9, -0.5, 0.5);
        let base = ConvConfig::new(n, c, k, h, w, 3, 3, 1, 1).with_threads(2);
        let want = run_fwd(&base, &x, &wt);
        for s in [Strategy::MinibatchFirst, Strategy::FeatureFirst, Strategy::Flat] {
            let got = run_fwd(&base.with_loop_order(s), &x, &wt);
            check_close(&got, &want, 1e-5, &format!("order {:?}", s));
        }
    }

    #[test]
    fn profiler_counts_exact_and_backward_leaks_no_slot() {
        use crate::telemetry::{self, Pass};
        let _g = telemetry::test_lock();
        let p = telemetry::install();
        let (n, c, k, h, w, r, s) = (1, 4, 6, 5, 5, 3, 3);
        let cfg = ConvConfig::new(n, c, k, h, w, r, s, 1, 1);
        let prim = ConvPrimitive::new(cfg);
        let mut rng = Rng::new(5);
        let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
        let wt = rng.vec_f32(k * c * r * s, -0.5, 0.5);
        let xp = layout::pack_conv_act(&x, n, c, h, w, cfg.bc, cfg.pad, cfg.pad);
        let wp = layout::pack_conv_weights(&wt, k, c, r, s, cfg.bk, cfg.bc);
        let mut op = vec![0.0; cfg.output_len()];
        prim.forward(&xp, &wp, None, &mut op);
        let before = p.slots().len();
        let (_dip, _) = prim.backward_data(&op, &wp);
        let (_dw, _db, _) = prim.update(&xp, &op);
        assert_eq!(
            p.slots().len(),
            before,
            "the backward pass's internal dual plan must not register its own slot"
        );
        let slot = p
            .slots()
            .into_iter()
            .find(|sl| sl.kind() == "conv" && sl.label() == "n1 c4 k6 5x5 f3x3/1")
            .expect("slot registered at construction");
        // bk = 6 -> kb = 1; bq = 5 -> one strip per row; P = 5 rows.
        let fwd = slot.pass_snapshot(Pass::Fwd);
        assert_eq!(fwd.calls, 1);
        assert_eq!(fwd.brgemm_calls, 5, "fwd: one BRGEMM per (n, kb, row, strip)");
        assert_eq!(fwd.flops, cfg.flops() as u64);
        // Stride-1 bwd runs the dual conv (c=6, k=4, 7x7 output, bq=7):
        // 1 * 1 * 7 * 1 = 7 calls, charged to this slot.
        let bwd = slot.pass_snapshot(Pass::Bwd);
        assert_eq!(bwd.calls, 1);
        assert_eq!(bwd.brgemm_calls, 7, "bwd charges the dual conv's calls here");
        // upd: one BRGEMM per (Kb x Cb) block x (R*S) tap = 1*1*9.
        let upd = slot.pass_snapshot(Pass::Upd);
        assert_eq!(upd.brgemm_calls, 9);
        telemetry::uninstall();
    }

    #[test]
    fn property_fwd_random_configs() {
        Prop::new("conv fwd matches naive").cases(15).run(|g| {
            let bc = g.usize(1..=4);
            let bk = g.usize(1..=6);
            let c = bc * g.usize(1..=3);
            let k = bk * g.usize(1..=3);
            let r = *g.choose(&[1usize, 3]);
            let st = g.usize(1..=2);
            let pad = if r == 1 { 0 } else { g.usize(0..=1) };
            let h = g.usize(r.max(3)..=9);
            let w = g.usize(r.max(3)..=9);
            let n = g.usize(1..=2);
            let x = g.vec_f32(n * c * h * w, -1.0, 1.0);
            let wt = g.vec_f32(k * c * r * r, -0.5, 0.5);
            let cfg = ConvConfig::new(n, c, k, h, w, r, r, st, pad);
            let got = run_fwd(&cfg, &x, &wt);
            let want = naive::conv_fwd(n, c, k, h, w, r, r, st, pad, &x, &wt);
            for i in 0..got.len() {
                if (got[i] - want[i]).abs() > 1e-3 {
                    return Err(format!(
                        "cfg {:?}: [{}] {} vs {}",
                        (n, c, k, h, w, r, st, pad),
                        i,
                        got[i],
                        want[i]
                    ));
                }
            }
            Ok(())
        });
    }
}
