//! DL primitives built on the single building block.
//!
//! Each of the paper's three workload families gets forward,
//! backward-by-data and weight-update passes, all expressed as loop nests
//! around [`crate::brgemm::BrgemmKernel`] plus fused element-wise stages —
//! the paper's central claim made concrete:
//!
//! * [`fc`]     — fully-connected layers (Algorithm 5; MLP / Transformer
//!   building block) + the large-GEMM baseline.
//! * [`lstm`]   — the LSTM cell (Algorithm 2) + the large-GEMM cell.
//! * [`conv`]   — direct convolutions (Algorithms 3/4) + the im2col and
//!   small-GEMM-loop baselines of Figure 1.
//! * [`eltwise`] — the fused non-GEMM stages (activations, Hadamard ops).
//! * [`pool`]   — average and max pooling on the blocked conv layouts (the
//!   conv-stack → classifier-head bridge of the CNN training driver).
//! * [`partition`] — the thread work-partitioning strategies (§3.2.2).
//! * [`naive`]  — straightforward reference implementations (oracles).

pub mod conv;
pub mod eltwise;
pub mod fc;
pub mod lstm;
pub mod naive;
pub mod partition;
pub mod pool;
