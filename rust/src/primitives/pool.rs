//! Average pooling over blocked conv activations.
//!
//! Pooling is one of the non-GEMM stages the paper's CNN pipeline needs
//! between convolution stages and the classifier head (ResNet-50 ends in a
//! global average pool). It operates directly on the conv primitives'
//! blocked layout `[N][Cb][H][W][bc]` — no unpack/repack round trip — and
//! is deliberately a simple bandwidth-bound sweep: like the element-wise
//! stages in [`super::eltwise`], its cost is data movement, not compute.
//!
//! The window average is linear, so the backward pass is an exact scatter
//! of `dY / (win_h·win_w)` back over each input window (overlapping
//! windows accumulate).

use crate::util::num::largest_divisor_le;

/// Pooling shape: input `[N][C][H][W]` (channel-blocked by `bc`), window
/// `win_h × win_w` slid with `stride` in both spatial dims.
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub win_h: usize,
    pub win_w: usize,
    pub stride: usize,
    /// Channel block of the (blocked) operand; must divide C.
    pub bc: usize,
}

impl PoolConfig {
    pub fn new(n: usize, c: usize, h: usize, w: usize, win: usize, stride: usize) -> PoolConfig {
        PoolConfig { n, c, h, w, win_h: win, win_w: win, stride, bc: largest_divisor_le(c, 64) }
    }

    /// Global average pool: one output pixel per channel (ResNet-style).
    pub fn global(n: usize, c: usize, h: usize, w: usize) -> PoolConfig {
        PoolConfig { n, c, h, w, win_h: h, win_w: w, stride: 1, bc: largest_divisor_le(c, 64) }
    }

    /// Override the channel block (rounded down to a divisor of C), e.g. to
    /// match the producing conv layer's `bk`.
    pub fn with_block(mut self, bc: usize) -> PoolConfig {
        assert!(bc >= 1, "block size must be >= 1");
        self.bc = largest_divisor_le(self.c, bc);
        self
    }

    fn validate(&self) {
        assert_eq!(self.c % self.bc, 0, "bc must divide C");
        assert!(self.win_h >= 1 && self.win_w >= 1 && self.stride >= 1);
        assert!(self.win_h <= self.h && self.win_w <= self.w, "window exceeds input");
    }

    /// Output spatial dims. Checked here (not only in `validate`) because
    /// shape helpers call these on configs that never reach `AvgPool::new`
    /// — an oversized window must fail loudly, not underflow.
    pub fn p(&self) -> usize {
        assert!(self.win_h <= self.h, "window exceeds input");
        (self.h - self.win_h) / self.stride + 1
    }
    pub fn q(&self) -> usize {
        assert!(self.win_w <= self.w, "window exceeds input");
        (self.w - self.win_w) / self.stride + 1
    }
    pub fn cb_ct(&self) -> usize {
        self.c / self.bc
    }
    pub fn input_len(&self) -> usize {
        self.n * self.cb_ct() * self.h * self.w * self.bc
    }
    pub fn output_len(&self) -> usize {
        self.n * self.cb_ct() * self.p() * self.q() * self.bc
    }
}

/// The average-pooling primitive (forward + backward) on blocked layouts.
pub struct AvgPool {
    pub cfg: PoolConfig,
}

impl AvgPool {
    pub fn new(cfg: PoolConfig) -> AvgPool {
        cfg.validate();
        AvgPool { cfg }
    }

    /// `y[n][cb][oj][oi][ic] = mean over the window of x` (blocked layouts,
    /// x `[N][Cb][H][W][bc]`, y `[N][Cb][P][Q][bc]`).
    pub fn forward(&self, x: &[f32], y: &mut [f32]) {
        let c = &self.cfg;
        assert_eq!(x.len(), c.input_len());
        assert_eq!(y.len(), c.output_len());
        let (cb, p, q) = (c.cb_ct(), c.p(), c.q());
        let inv = 1.0 / (c.win_h * c.win_w) as f32;
        for n in 0..c.n {
            for icb in 0..cb {
                let plane = (n * cb + icb) * c.h * c.w * c.bc;
                for oj in 0..p {
                    for oi in 0..q {
                        let dst = (((n * cb + icb) * p + oj) * q + oi) * c.bc;
                        y[dst..dst + c.bc].fill(0.0);
                        for jj in 0..c.win_h {
                            for ii in 0..c.win_w {
                                let src = plane
                                    + ((oj * c.stride + jj) * c.w + (oi * c.stride + ii)) * c.bc;
                                for ic in 0..c.bc {
                                    y[dst + ic] += x[src + ic];
                                }
                            }
                        }
                        for v in &mut y[dst..dst + c.bc] {
                            *v *= inv;
                        }
                    }
                }
            }
        }
    }

    /// Input gradient: scatter `dy / (win_h·win_w)` back over each window
    /// (overlapping windows accumulate). Returns dX in the input geometry.
    pub fn backward(&self, dy: &[f32]) -> Vec<f32> {
        let c = &self.cfg;
        assert_eq!(dy.len(), c.output_len());
        let (cb, p, q) = (c.cb_ct(), c.p(), c.q());
        let inv = 1.0 / (c.win_h * c.win_w) as f32;
        let mut dx = vec![0.0f32; c.input_len()];
        for n in 0..c.n {
            for icb in 0..cb {
                let plane = (n * cb + icb) * c.h * c.w * c.bc;
                for oj in 0..p {
                    for oi in 0..q {
                        let src = (((n * cb + icb) * p + oj) * q + oi) * c.bc;
                        for jj in 0..c.win_h {
                            for ii in 0..c.win_w {
                                let dst = plane
                                    + ((oj * c.stride + jj) * c.w + (oi * c.stride + ii)) * c.bc;
                                for ic in 0..c.bc {
                                    dx[dst + ic] += dy[src + ic] * inv;
                                }
                            }
                        }
                    }
                }
            }
        }
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::layout::{pack_conv_act, unpack_conv_act};
    use crate::util::rng::Rng;

    /// Plain-NCHW oracle.
    fn naive_avg_pool(
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        win_h: usize,
        win_w: usize,
        stride: usize,
        x: &[f32],
    ) -> Vec<f32> {
        let p = (h - win_h) / stride + 1;
        let q = (w - win_w) / stride + 1;
        let mut y = vec![0.0f32; n * c * p * q];
        for ni in 0..n {
            for cc in 0..c {
                for oj in 0..p {
                    for oi in 0..q {
                        let mut acc = 0.0f64;
                        for jj in 0..win_h {
                            for ii in 0..win_w {
                                acc += x[((ni * c + cc) * h + (oj * stride + jj)) * w
                                    + (oi * stride + ii)] as f64;
                            }
                        }
                        y[((ni * c + cc) * p + oj) * q + oi] =
                            (acc / (win_h * win_w) as f64) as f32;
                    }
                }
            }
        }
        y
    }

    fn naive_avg_pool_bwd(
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        win_h: usize,
        win_w: usize,
        stride: usize,
        dy: &[f32],
    ) -> Vec<f32> {
        let p = (h - win_h) / stride + 1;
        let q = (w - win_w) / stride + 1;
        let inv = 1.0 / (win_h * win_w) as f32;
        let mut dx = vec![0.0f32; n * c * h * w];
        for ni in 0..n {
            for cc in 0..c {
                for oj in 0..p {
                    for oi in 0..q {
                        let g = dy[((ni * c + cc) * p + oj) * q + oi] * inv;
                        for jj in 0..win_h {
                            for ii in 0..win_w {
                                dx[((ni * c + cc) * h + (oj * stride + jj)) * w
                                    + (oi * stride + ii)] += g;
                            }
                        }
                    }
                }
            }
        }
        dx
    }

    #[test]
    fn forward_matches_naive_various_shapes() {
        // (n, c, h, w, win, stride, bc): non-overlapping, overlapping, global.
        for &(n, c, h, w, win, stride, bc) in &[
            (2usize, 4usize, 6usize, 6usize, 2usize, 2usize, 2usize),
            (1, 6, 5, 7, 3, 1, 3),
            (2, 4, 4, 4, 4, 1, 4), // global
        ] {
            let mut rng = Rng::new((c * h + w) as u64);
            let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
            let cfg = PoolConfig::new(n, c, h, w, win, stride).with_block(bc);
            let pool = AvgPool::new(cfg);
            let xp = pack_conv_act(&x, n, c, h, w, cfg.bc, 0, 0);
            let mut yp = vec![0.0; cfg.output_len()];
            pool.forward(&xp, &mut yp);
            let y = unpack_conv_act(&yp, n, c, cfg.p(), cfg.q(), cfg.bc, 0, 0);
            let want = naive_avg_pool(n, c, h, w, win, win, stride, &x);
            for i in 0..y.len() {
                assert!(
                    (y[i] - want[i]).abs() < 1e-5,
                    "{:?} y[{}]: {} vs {}",
                    (n, c, h, w, win, stride, bc),
                    i,
                    y[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn backward_matches_naive_including_overlap() {
        for &(n, c, h, w, win, stride) in
            &[(1usize, 4usize, 6usize, 6usize, 2usize, 2usize), (2, 2, 5, 5, 3, 1)]
        {
            let cfg = PoolConfig::new(n, c, h, w, win, stride);
            let pool = AvgPool::new(cfg);
            let mut rng = Rng::new(9);
            let dy = rng.vec_f32(n * c * cfg.p() * cfg.q(), -1.0, 1.0);
            let dyp = pack_conv_act(&dy, n, c, cfg.p(), cfg.q(), cfg.bc, 0, 0);
            let dxp = pool.backward(&dyp);
            let dx = unpack_conv_act(&dxp, n, c, h, w, cfg.bc, 0, 0);
            let want = naive_avg_pool_bwd(n, c, h, w, win, win, stride, &dy);
            for i in 0..dx.len() {
                assert!(
                    (dx[i] - want[i]).abs() < 1e-5,
                    "{:?} dx[{}]: {} vs {}",
                    (n, c, h, w, win, stride),
                    i,
                    dx[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn global_pool_is_per_channel_mean() {
        let (n, c, h, w) = (2, 4, 3, 5);
        let mut rng = Rng::new(4);
        let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
        let cfg = PoolConfig::global(n, c, h, w);
        assert_eq!((cfg.p(), cfg.q()), (1, 1));
        let pool = AvgPool::new(cfg);
        let xp = pack_conv_act(&x, n, c, h, w, cfg.bc, 0, 0);
        let mut yp = vec![0.0; cfg.output_len()];
        pool.forward(&xp, &mut yp);
        // Output [N][Cb][1][1][bc] flattens to plain [N][C].
        for ni in 0..n {
            for cc in 0..c {
                let mean: f32 = x[(ni * c + cc) * h * w..(ni * c + cc + 1) * h * w]
                    .iter()
                    .sum::<f32>()
                    / (h * w) as f32;
                assert!((yp[ni * c + cc] - mean).abs() < 1e-5, "({}, {})", ni, cc);
            }
        }
    }

    #[test]
    #[should_panic(expected = "window exceeds input")]
    fn oversized_window_rejected() {
        AvgPool::new(PoolConfig::new(1, 4, 4, 4, 5, 1));
    }
}
