//! Pooling over blocked conv activations (average and max).
//!
//! Pooling is one of the non-GEMM stages the paper's CNN pipeline needs
//! between convolution stages and the classifier head (ResNet-50 ends in a
//! global average pool and starts with a 3×3/s2 max pool). It operates
//! directly on the conv primitives' blocked layout `[N][Cb][H][W][bc]` —
//! no unpack/repack round trip — and is deliberately a simple
//! bandwidth-bound sweep: like the element-wise stages in
//! [`super::eltwise`], its cost is data movement, not compute. Both
//! directions parallelise over the `(N × Cb)` planes — each plane is
//! written by exactly one task, so threading never changes a result.
//!
//! [`AvgPool`]: the window average is linear, so the backward pass is an
//! exact scatter of `dY / (win_h·win_w)` back over each input window
//! (overlapping windows accumulate).
//!
//! [`MaxPool`]: the forward pass records each window's argmax (flat input
//! index, first-maximum tie-break); the backward pass routes `dY` to
//! exactly those positions — no recomputation of the forward sweep.

use crate::util::num::largest_divisor_le;
use crate::util::pool::{parallel_for, SharedMut};

/// Pooling shape: input `[N][C][H][W]` (channel-blocked by `bc`), window
/// `win_h × win_w` slid with `stride` in both spatial dims.
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub win_h: usize,
    pub win_w: usize,
    pub stride: usize,
    /// Channel block of the (blocked) operand; must divide C.
    pub bc: usize,
    pub nthreads: usize,
}

impl PoolConfig {
    pub fn new(n: usize, c: usize, h: usize, w: usize, win: usize, stride: usize) -> PoolConfig {
        PoolConfig {
            n,
            c,
            h,
            w,
            win_h: win,
            win_w: win,
            stride,
            bc: largest_divisor_le(c, 64),
            nthreads: 1,
        }
    }

    /// Global average pool: one output pixel per channel (ResNet-style).
    pub fn global(n: usize, c: usize, h: usize, w: usize) -> PoolConfig {
        PoolConfig {
            n,
            c,
            h,
            w,
            win_h: h,
            win_w: w,
            stride: 1,
            bc: largest_divisor_le(c, 64),
            nthreads: 1,
        }
    }

    /// Override the channel block (rounded down to a divisor of C), e.g. to
    /// match the producing conv layer's `bk`.
    pub fn with_block(mut self, bc: usize) -> PoolConfig {
        assert!(bc >= 1, "block size must be >= 1");
        self.bc = largest_divisor_le(self.c, bc);
        self
    }

    pub fn with_threads(mut self, t: usize) -> PoolConfig {
        self.nthreads = t;
        self
    }

    fn validate(&self) {
        assert_eq!(self.c % self.bc, 0, "bc must divide C");
        assert!(self.win_h >= 1 && self.win_w >= 1 && self.stride >= 1);
        assert!(self.win_h <= self.h && self.win_w <= self.w, "window exceeds input");
        assert!(self.nthreads >= 1);
    }

    /// Output spatial dims. Checked here (not only in `validate`) because
    /// shape helpers call these on configs that never reach the pool
    /// constructors — an oversized window must fail loudly, not underflow.
    pub fn p(&self) -> usize {
        assert!(self.win_h <= self.h, "window exceeds input");
        (self.h - self.win_h) / self.stride + 1
    }
    pub fn q(&self) -> usize {
        assert!(self.win_w <= self.w, "window exceeds input");
        (self.w - self.win_w) / self.stride + 1
    }
    pub fn cb_ct(&self) -> usize {
        self.c / self.bc
    }
    pub fn input_len(&self) -> usize {
        self.n * self.cb_ct() * self.h * self.w * self.bc
    }
    pub fn output_len(&self) -> usize {
        self.n * self.cb_ct() * self.p() * self.q() * self.bc
    }
}

/// The average-pooling primitive (forward + backward) on blocked layouts.
pub struct AvgPool {
    pub cfg: PoolConfig,
}

impl AvgPool {
    pub fn new(cfg: PoolConfig) -> AvgPool {
        cfg.validate();
        AvgPool { cfg }
    }

    /// `y[n][cb][oj][oi][ic] = mean over the window of x` (blocked layouts,
    /// x `[N][Cb][H][W][bc]`, y `[N][Cb][P][Q][bc]`). Parallel over the
    /// `(N × Cb)` planes — disjoint output regions per task.
    pub fn forward(&self, x: &[f32], y: &mut [f32]) {
        let c = &self.cfg;
        assert_eq!(x.len(), c.input_len());
        assert_eq!(y.len(), c.output_len());
        let (cb, p, q) = (c.cb_ct(), c.p(), c.q());
        let inv = 1.0 / (c.win_h * c.win_w) as f32;
        let oplane = p * q * c.bc;
        let shared = &SharedMut::new(y);
        parallel_for(c.nthreads, c.n * cb, |_tid, t| {
            let plane = t * c.h * c.w * c.bc;
            // SAFETY: one output plane per task, tasks disjoint.
            let yp = unsafe { shared.slice(t * oplane, oplane) };
            for oj in 0..p {
                for oi in 0..q {
                    let dst = (oj * q + oi) * c.bc;
                    yp[dst..dst + c.bc].fill(0.0);
                    for jj in 0..c.win_h {
                        for ii in 0..c.win_w {
                            let src = plane
                                + ((oj * c.stride + jj) * c.w + (oi * c.stride + ii)) * c.bc;
                            for ic in 0..c.bc {
                                yp[dst + ic] += x[src + ic];
                            }
                        }
                    }
                    for v in &mut yp[dst..dst + c.bc] {
                        *v *= inv;
                    }
                }
            }
        });
    }

    /// Input gradient: scatter `dy / (win_h·win_w)` back over each window
    /// (overlapping windows accumulate — serially within a plane, so the
    /// parallel sweep is deterministic). Returns dX in the input geometry.
    pub fn backward(&self, dy: &[f32]) -> Vec<f32> {
        let c = &self.cfg;
        assert_eq!(dy.len(), c.output_len());
        let (cb, p, q) = (c.cb_ct(), c.p(), c.q());
        let inv = 1.0 / (c.win_h * c.win_w) as f32;
        let mut dx = vec![0.0f32; c.input_len()];
        let iplane = c.h * c.w * c.bc;
        let shared = &SharedMut::new(&mut dx);
        parallel_for(c.nthreads, c.n * cb, |_tid, t| {
            // SAFETY: one input plane per task, tasks disjoint.
            let dxp = unsafe { shared.slice(t * iplane, iplane) };
            for oj in 0..p {
                for oi in 0..q {
                    let src = (t * p * q + oj * q + oi) * c.bc;
                    for jj in 0..c.win_h {
                        for ii in 0..c.win_w {
                            let dst =
                                ((oj * c.stride + jj) * c.w + (oi * c.stride + ii)) * c.bc;
                            for ic in 0..c.bc {
                                dxp[dst + ic] += dy[src + ic] * inv;
                            }
                        }
                    }
                }
            }
        });
        dx
    }
}

/// The max-pooling primitive on blocked layouts: forward records the
/// argmax of every window, backward routes the gradient to exactly those
/// input positions.
pub struct MaxPool {
    pub cfg: PoolConfig,
}

impl MaxPool {
    pub fn new(cfg: PoolConfig) -> MaxPool {
        cfg.validate();
        assert!(cfg.input_len() <= u32::MAX as usize, "argmax indices are u32");
        MaxPool { cfg }
    }

    /// `y[..] = max over the window of x`; `argmax[..]` gets the flat index
    /// into `x` of each window's winner (first maximum wins ties, so the
    /// routed backward is deterministic). Parallel over `(N × Cb)` planes.
    pub fn forward(&self, x: &[f32], y: &mut [f32], argmax: &mut [u32]) {
        let c = &self.cfg;
        assert_eq!(x.len(), c.input_len());
        assert_eq!(y.len(), c.output_len());
        assert_eq!(argmax.len(), c.output_len());
        let (cb, p, q) = (c.cb_ct(), c.p(), c.q());
        let oplane = p * q * c.bc;
        let shared_y = &SharedMut::new(y);
        let shared_am: &SharedMut<u32> = &SharedMut::new(argmax);
        parallel_for(c.nthreads, c.n * cb, |_tid, t| {
            let plane = t * c.h * c.w * c.bc;
            // SAFETY: one output plane per task, tasks disjoint (both
            // buffers share the output geometry).
            let yp = unsafe { shared_y.slice(t * oplane, oplane) };
            let ap = unsafe { shared_am.slice(t * oplane, oplane) };
            for oj in 0..p {
                for oi in 0..q {
                    let dst = (oj * q + oi) * c.bc;
                    for ic in 0..c.bc {
                        // Seed from the window's first element (not -inf /
                        // index 0): an all-NaN window then still records an
                        // in-window argmax instead of a plane-0 index that
                        // would misroute (or panic) in backward.
                        let first =
                            plane + ((oj * c.stride) * c.w + (oi * c.stride)) * c.bc + ic;
                        let mut best = x[first];
                        let mut best_at = first as u32;
                        for jj in 0..c.win_h {
                            for ii in 0..c.win_w {
                                let src = plane
                                    + ((oj * c.stride + jj) * c.w + (oi * c.stride + ii)) * c.bc
                                    + ic;
                                if x[src] > best {
                                    best = x[src];
                                    best_at = src as u32;
                                }
                            }
                        }
                        yp[dst + ic] = best;
                        ap[dst + ic] = best_at;
                    }
                }
            }
        });
    }

    /// Input gradient: `dx[argmax[j]] += dy[j]` — the routed scatter
    /// (overlapping windows whose winners coincide accumulate; all of one
    /// plane's argmax targets lie in that plane, so the parallel sweep
    /// writes disjoint regions).
    pub fn backward(&self, dy: &[f32], argmax: &[u32]) -> Vec<f32> {
        let c = &self.cfg;
        assert_eq!(dy.len(), c.output_len());
        assert_eq!(argmax.len(), c.output_len());
        let (cb, p, q) = (c.cb_ct(), c.p(), c.q());
        let mut dx = vec![0.0f32; c.input_len()];
        let iplane = c.h * c.w * c.bc;
        let oplane = p * q * c.bc;
        let shared = &SharedMut::new(&mut dx);
        parallel_for(c.nthreads, c.n * cb, |_tid, t| {
            // SAFETY: plane t's argmax indices all point into input plane t
            // (forward only ever scans that plane); tasks disjoint.
            let dxp = unsafe { shared.slice(t * iplane, iplane) };
            for j in 0..oplane {
                let at = argmax[t * oplane + j] as usize;
                debug_assert!((t * iplane..(t + 1) * iplane).contains(&at));
                dxp[at - t * iplane] += dy[t * oplane + j];
            }
        });
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::layout::{pack_conv_act, unpack_conv_act};
    use crate::util::rng::Rng;

    /// Plain-NCHW oracle.
    fn naive_avg_pool(
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        win_h: usize,
        win_w: usize,
        stride: usize,
        x: &[f32],
    ) -> Vec<f32> {
        let p = (h - win_h) / stride + 1;
        let q = (w - win_w) / stride + 1;
        let mut y = vec![0.0f32; n * c * p * q];
        for ni in 0..n {
            for cc in 0..c {
                for oj in 0..p {
                    for oi in 0..q {
                        let mut acc = 0.0f64;
                        for jj in 0..win_h {
                            for ii in 0..win_w {
                                acc += x[((ni * c + cc) * h + (oj * stride + jj)) * w
                                    + (oi * stride + ii)] as f64;
                            }
                        }
                        y[((ni * c + cc) * p + oj) * q + oi] =
                            (acc / (win_h * win_w) as f64) as f32;
                    }
                }
            }
        }
        y
    }

    fn naive_avg_pool_bwd(
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        win_h: usize,
        win_w: usize,
        stride: usize,
        dy: &[f32],
    ) -> Vec<f32> {
        let p = (h - win_h) / stride + 1;
        let q = (w - win_w) / stride + 1;
        let inv = 1.0 / (win_h * win_w) as f32;
        let mut dx = vec![0.0f32; n * c * h * w];
        for ni in 0..n {
            for cc in 0..c {
                for oj in 0..p {
                    for oi in 0..q {
                        let g = dy[((ni * c + cc) * p + oj) * q + oi] * inv;
                        for jj in 0..win_h {
                            for ii in 0..win_w {
                                dx[((ni * c + cc) * h + (oj * stride + jj)) * w
                                    + (oi * stride + ii)] += g;
                            }
                        }
                    }
                }
            }
        }
        dx
    }

    /// Plain-NCHW max-pool oracle (forward + routed backward in one).
    #[allow(clippy::too_many_arguments)]
    fn naive_max_pool(
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        win: usize,
        stride: usize,
        x: &[f32],
        dy: &[f32],
    ) -> (Vec<f32>, Vec<f32>) {
        let p = (h - win) / stride + 1;
        let q = (w - win) / stride + 1;
        let mut y = vec![0.0f32; n * c * p * q];
        let mut dx = vec![0.0f32; n * c * h * w];
        for ni in 0..n {
            for cc in 0..c {
                for oj in 0..p {
                    for oi in 0..q {
                        let mut best = f32::NEG_INFINITY;
                        let mut at = 0usize;
                        for jj in 0..win {
                            for ii in 0..win {
                                let src = ((ni * c + cc) * h + (oj * stride + jj)) * w
                                    + (oi * stride + ii);
                                if x[src] > best {
                                    best = x[src];
                                    at = src;
                                }
                            }
                        }
                        let o = ((ni * c + cc) * p + oj) * q + oi;
                        y[o] = best;
                        dx[at] += dy[o];
                    }
                }
            }
        }
        (y, dx)
    }

    #[test]
    fn forward_matches_naive_various_shapes() {
        // (n, c, h, w, win, stride, bc): non-overlapping, overlapping, global.
        for &(n, c, h, w, win, stride, bc) in &[
            (2usize, 4usize, 6usize, 6usize, 2usize, 2usize, 2usize),
            (1, 6, 5, 7, 3, 1, 3),
            (2, 4, 4, 4, 4, 1, 4), // global
        ] {
            let mut rng = Rng::new((c * h + w) as u64);
            let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
            let cfg = PoolConfig::new(n, c, h, w, win, stride).with_block(bc);
            let pool = AvgPool::new(cfg);
            let xp = pack_conv_act(&x, n, c, h, w, cfg.bc, 0, 0);
            let mut yp = vec![0.0; cfg.output_len()];
            pool.forward(&xp, &mut yp);
            let y = unpack_conv_act(&yp, n, c, cfg.p(), cfg.q(), cfg.bc, 0, 0);
            let want = naive_avg_pool(n, c, h, w, win, win, stride, &x);
            for i in 0..y.len() {
                assert!(
                    (y[i] - want[i]).abs() < 1e-5,
                    "{:?} y[{}]: {} vs {}",
                    (n, c, h, w, win, stride, bc),
                    i,
                    y[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn backward_matches_naive_including_overlap() {
        for &(n, c, h, w, win, stride) in
            &[(1usize, 4usize, 6usize, 6usize, 2usize, 2usize), (2, 2, 5, 5, 3, 1)]
        {
            let cfg = PoolConfig::new(n, c, h, w, win, stride);
            let pool = AvgPool::new(cfg);
            let mut rng = Rng::new(9);
            let dy = rng.vec_f32(n * c * cfg.p() * cfg.q(), -1.0, 1.0);
            let dyp = pack_conv_act(&dy, n, c, cfg.p(), cfg.q(), cfg.bc, 0, 0);
            let dxp = pool.backward(&dyp);
            let dx = unpack_conv_act(&dxp, n, c, h, w, cfg.bc, 0, 0);
            let want = naive_avg_pool_bwd(n, c, h, w, win, win, stride, &dy);
            for i in 0..dx.len() {
                assert!(
                    (dx[i] - want[i]).abs() < 1e-5,
                    "{:?} dx[{}]: {} vs {}",
                    (n, c, h, w, win, stride),
                    i,
                    dx[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn parallel_pool_is_bit_identical() {
        let (n, c, h, w, win, stride) = (3, 8, 6, 6, 3, 1);
        let mut rng = Rng::new(31);
        let base = PoolConfig::new(n, c, h, w, win, stride).with_block(4);
        let x = rng.vec_f32(base.input_len(), -1.0, 1.0);
        let dy = rng.vec_f32(base.output_len(), -1.0, 1.0);
        let p1 = AvgPool::new(base);
        let p4 = AvgPool::new(base.with_threads(4));
        let (mut y1, mut y4) = (vec![0.0; base.output_len()], vec![0.0; base.output_len()]);
        p1.forward(&x, &mut y1);
        p4.forward(&x, &mut y4);
        assert_eq!(y1, y4, "avg fwd threads must not change bits");
        assert_eq!(p1.backward(&dy), p4.backward(&dy), "avg bwd threads must not change bits");
        let (m1, m4) = (MaxPool::new(base), MaxPool::new(base.with_threads(4)));
        let mut am1 = vec![0u32; base.output_len()];
        let mut am4 = vec![0u32; base.output_len()];
        m1.forward(&x, &mut y1, &mut am1);
        m4.forward(&x, &mut y4, &mut am4);
        assert_eq!(y1, y4, "max fwd threads must not change bits");
        assert_eq!(am1, am4, "argmax threads must not change routing");
        assert_eq!(m1.backward(&dy, &am1), m4.backward(&dy, &am4));
    }

    #[test]
    fn max_pool_matches_naive_oracle() {
        // Non-overlapping, overlapping (routing accumulates), and strided.
        for &(n, c, h, w, win, stride, bc) in &[
            (2usize, 4usize, 6usize, 6usize, 2usize, 2usize, 2usize),
            (1, 6, 5, 5, 3, 1, 3),
            (2, 2, 7, 7, 3, 2, 2),
        ] {
            let mut rng = Rng::new((h * 7 + win) as u64);
            let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
            let cfg = PoolConfig::new(n, c, h, w, win, stride).with_block(bc);
            let pool = MaxPool::new(cfg);
            let dy = rng.vec_f32(n * c * cfg.p() * cfg.q(), -1.0, 1.0);
            let xp = pack_conv_act(&x, n, c, h, w, cfg.bc, 0, 0);
            let dyp = pack_conv_act(&dy, n, c, cfg.p(), cfg.q(), cfg.bc, 0, 0);
            let mut yp = vec![0.0; cfg.output_len()];
            let mut am = vec![0u32; cfg.output_len()];
            pool.forward(&xp, &mut yp, &mut am);
            let dxp = pool.backward(&dyp, &am);
            let y = unpack_conv_act(&yp, n, c, cfg.p(), cfg.q(), cfg.bc, 0, 0);
            let dx = unpack_conv_act(&dxp, n, c, h, w, cfg.bc, 0, 0);
            let (y_want, dx_want) = naive_max_pool(n, c, h, w, win, stride, &x, &dy);
            for i in 0..y.len() {
                assert!(
                    (y[i] - y_want[i]).abs() < 1e-6,
                    "{:?} y[{}]: {} vs {}",
                    (n, c, h, w, win, stride),
                    i,
                    y[i],
                    y_want[i]
                );
            }
            for i in 0..dx.len() {
                assert!(
                    (dx[i] - dx_want[i]).abs() < 1e-6,
                    "{:?} dx[{}]: {} vs {}",
                    (n, c, h, w, win, stride),
                    i,
                    dx[i],
                    dx_want[i]
                );
            }
        }
    }

    #[test]
    fn max_pool_ties_route_to_first_maximum() {
        // A constant plane: every window's winner is its first element, so
        // dX gets the whole dY mass at stride-aligned positions.
        let cfg = PoolConfig::new(1, 1, 4, 4, 2, 2).with_block(1);
        let pool = MaxPool::new(cfg);
        let x = vec![1.0f32; cfg.input_len()];
        let mut y = vec![0.0; cfg.output_len()];
        let mut am = vec![0u32; cfg.output_len()];
        pool.forward(&x, &mut y, &mut am);
        assert!(y.iter().all(|&v| v == 1.0));
        assert_eq!(am, vec![0, 2, 8, 10], "first element of each window wins");
        let dx = pool.backward(&[1.0, 2.0, 3.0, 4.0], &am);
        let mut want = vec![0.0f32; 16];
        want[0] = 1.0;
        want[2] = 2.0;
        want[8] = 3.0;
        want[10] = 4.0;
        assert_eq!(dx, want);
    }

    #[test]
    fn max_pool_nan_window_routes_in_plane() {
        // A NaN-poisoned window must still record an in-window argmax (the
        // seed-from-first-element rule): y propagates the NaN and backward
        // routes into the right plane instead of underflowing into plane 0.
        let cfg = PoolConfig::new(2, 1, 4, 4, 2, 2).with_block(1);
        let pool = MaxPool::new(cfg);
        let mut x = vec![1.0f32; cfg.input_len()];
        // Poison one full window in the second image's plane.
        let plane1 = 16; // n=1, cb=0
        for &off in &[0usize, 1, 4, 5] {
            x[plane1 + off] = f32::NAN;
        }
        let mut y = vec![0.0; cfg.output_len()];
        let mut am = vec![0u32; cfg.output_len()];
        pool.forward(&x, &mut y, &mut am);
        let out1 = 4; // plane 1's first output element
        assert!(y[out1].is_nan(), "NaN window propagates NaN");
        assert_eq!(am[out1], plane1 as u32, "argmax stays inside the window");
        let dy = vec![1.0f32; cfg.output_len()];
        let dx = pool.backward(&dy, &am); // must not panic
        assert_eq!(dx[plane1], 1.0);
    }

    #[test]
    fn global_pool_is_per_channel_mean() {
        let (n, c, h, w) = (2, 4, 3, 5);
        let mut rng = Rng::new(4);
        let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
        let cfg = PoolConfig::global(n, c, h, w);
        assert_eq!((cfg.p(), cfg.q()), (1, 1));
        let pool = AvgPool::new(cfg);
        let xp = pack_conv_act(&x, n, c, h, w, cfg.bc, 0, 0);
        let mut yp = vec![0.0; cfg.output_len()];
        pool.forward(&xp, &mut yp);
        // Output [N][Cb][1][1][bc] flattens to plain [N][C].
        for ni in 0..n {
            for cc in 0..c {
                let mean: f32 = x[(ni * c + cc) * h * w..(ni * c + cc + 1) * h * w]
                    .iter()
                    .sum::<f32>()
                    / (h * w) as f32;
                assert!((yp[ni * c + cc] - mean).abs() < 1e-5, "({}, {})", ni, cc);
            }
        }
    }

    #[test]
    #[should_panic(expected = "window exceeds input")]
    fn oversized_window_rejected() {
        AvgPool::new(PoolConfig::new(1, 4, 4, 4, 5, 1));
    }
}
