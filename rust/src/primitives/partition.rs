//! Work-partitioning strategies for the primitives (paper §3.2.2).
//!
//! The paper parallelises each primitive by assigning independent output
//! work items to threads, choosing among strategies based on the layer
//! shape: split on the mini-batch first (weight reuse from shared cache),
//! fall back to the full flattened task space when the mini-batch alone
//! has insufficient parallelism, or split on output feature blocks first
//! when the weights are large (so each thread touches a slice of the
//! weight tensor it can cache-block).

use crate::util::pool::chunk_range;

/// How to map output work items to threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Split the mini-batch dimension; every thread covers all feature
    /// blocks (maximises weight sharing).
    MinibatchFirst,
    /// Split output-feature blocks; every thread covers the whole
    /// mini-batch (minimises per-thread weight footprint).
    FeatureFirst,
    /// Flatten all dims and block-partition (maximum parallel slack).
    Flat,
}

/// A 2-D output task space (rows = mini-batch blocks, cols = feature
/// blocks) partitioned for `nthreads`.
#[derive(Debug, Clone)]
pub struct Partition2d {
    pub rows: usize,
    pub cols: usize,
    pub strategy: Strategy,
    pub nthreads: usize,
}

impl Partition2d {
    pub fn new(rows: usize, cols: usize, nthreads: usize, strategy: Strategy) -> Partition2d {
        Partition2d { rows, cols, strategy, nthreads }
    }

    /// Choose a strategy the way the paper describes: mini-batch first if it
    /// alone offers ≥ 1 row per thread, else flat; feature-first when the
    /// per-task weight slice is large (`big_weights`).
    pub fn auto(rows: usize, cols: usize, nthreads: usize, big_weights: bool) -> Partition2d {
        let strategy = if big_weights && cols >= nthreads {
            Strategy::FeatureFirst
        } else if rows >= nthreads {
            Strategy::MinibatchFirst
        } else {
            Strategy::Flat
        };
        Partition2d::new(rows, cols, nthreads, strategy)
    }

    /// The (row, col) work items of thread `tid`, in execution order.
    /// Iterating the mini-batch innermost is what gives the weight-block
    /// reuse the paper points out after Algorithm 2.
    pub fn tasks(&self, tid: usize) -> Vec<(usize, usize)> {
        match self.strategy {
            Strategy::MinibatchFirst => {
                let (lo, hi) = chunk_range(self.rows, self.nthreads, tid);
                // cols outer, rows inner: each weight block is loaded once
                // per thread and reused across its mini-batch rows.
                let mut out = Vec::with_capacity((hi - lo) * self.cols);
                for c in 0..self.cols {
                    for r in lo..hi {
                        out.push((r, c));
                    }
                }
                out
            }
            Strategy::FeatureFirst => {
                let (lo, hi) = chunk_range(self.cols, self.nthreads, tid);
                let mut out = Vec::with_capacity((hi - lo) * self.rows);
                for c in lo..hi {
                    for r in 0..self.rows {
                        out.push((r, c));
                    }
                }
                out
            }
            Strategy::Flat => {
                let total = self.rows * self.cols;
                let (lo, hi) = chunk_range(total, self.nthreads, tid);
                (lo..hi).map(|t| (t / self.cols, t % self.cols)).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use std::collections::HashSet;

    fn check_cover(p: &Partition2d) -> Result<(), String> {
        let mut seen = HashSet::new();
        let mut max_load = 0usize;
        let mut min_load = usize::MAX;
        for tid in 0..p.nthreads {
            let tasks = p.tasks(tid);
            max_load = max_load.max(tasks.len());
            min_load = min_load.min(tasks.len());
            for t in tasks {
                if t.0 >= p.rows || t.1 >= p.cols {
                    return Err(format!("task {:?} out of bounds", t));
                }
                if !seen.insert(t) {
                    return Err(format!("task {:?} assigned twice", t));
                }
            }
        }
        if seen.len() != p.rows * p.cols {
            return Err(format!("covered {} of {} tasks", seen.len(), p.rows * p.cols));
        }
        // Load balance bound: Flat ⇒ ±1 task; dimension splits ⇒ ±1 slice.
        let bound = match p.strategy {
            Strategy::Flat => 1,
            Strategy::MinibatchFirst => p.cols,
            Strategy::FeatureFirst => p.rows,
        };
        if max_load - min_load > bound {
            return Err(format!(
                "imbalance {} > {} for {:?}",
                max_load - min_load,
                bound,
                p.strategy
            ));
        }
        Ok(())
    }

    #[test]
    fn all_strategies_cover_disjointly() {
        for &strategy in &[Strategy::MinibatchFirst, Strategy::FeatureFirst, Strategy::Flat] {
            for &(r, c, t) in &[(8, 4, 4), (3, 7, 5), (1, 1, 4), (16, 16, 7)] {
                let p = Partition2d::new(r, c, t, strategy);
                check_cover(&p).unwrap();
            }
        }
    }

    #[test]
    fn minibatch_first_iterates_batch_inner() {
        let p = Partition2d::new(4, 3, 2, Strategy::MinibatchFirst);
        let t0 = p.tasks(0);
        // rows {0,1}, all cols; batch (row) must vary fastest within a col.
        assert_eq!(t0[0], (0, 0));
        assert_eq!(t0[1], (1, 0));
        assert_eq!(t0[2], (0, 1));
    }

    #[test]
    fn auto_picks_documented_strategies() {
        assert_eq!(Partition2d::auto(16, 4, 8, false).strategy, Strategy::MinibatchFirst);
        assert_eq!(Partition2d::auto(2, 16, 8, false).strategy, Strategy::Flat);
        assert_eq!(Partition2d::auto(2, 16, 8, true).strategy, Strategy::FeatureFirst);
    }

    #[test]
    fn property_partition_invariants() {
        Prop::new("partition covers exactly once").cases(80).run(|g| {
            let rows = g.usize(1..=24);
            let cols = g.usize(1..=24);
            let nthreads = g.usize(1..=9);
            let strategy =
                *g.choose(&[Strategy::MinibatchFirst, Strategy::FeatureFirst, Strategy::Flat]);
            check_cover(&Partition2d::new(rows, cols, nthreads, strategy))
        });
    }
}
