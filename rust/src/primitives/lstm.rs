//! The LSTM cell via the batch-reduce GEMM kernel (paper §3.1, Algorithm 2)
//! plus the coarse-grained large-GEMM cell of §3.1.1 as the baseline.
//!
//! Data-flow formulation: the output/gate tensors are divided into
//! `bn×bk` work-item blocks; for each block and time-step, one BRGEMM call
//! (batch = Cb) accumulates `W_z·x_t`, a second (batch = Kb, β = 1)
//! accumulates `R_z·h_{t-1}` and applies bias + gate activation *while the
//! block is hot in cache*; the LSTM state recurrences (Eq. 5-6) follow on
//! the same hot block. Threads synchronise per time-step (h_t feeds t+1).
//!
//! Layouts: weights `W[4][Kb][Cb][bc][bk]`, recurrent `R[4][Kb][Kb][bk][bk]`
//! (blocked per §3.1.2 to avoid power-of-two strided accesses); activations
//! stay non-blocked — `x[T][N][C]`, `h/s[T+1][N][K]`, gates `[4][T][N][K]`
//! — since strided rows are free for the microkernel's A operand.
//! Gate order throughout: 0 = i (input), 1 = g (candidate, the paper's
//! c̃_t), 2 = f (forget), 3 = o (output).

use crate::brgemm::{BrgemmDesc, BrgemmKernel, Epilogue, Gemm};
use crate::primitives::eltwise::Act;
use crate::primitives::partition::{Partition2d, Strategy};
use crate::telemetry::{self, Pass, PrimSlot};
use crate::tensor::layout::{pack_weights_2d, transpose_packed_2d, unpack_weights_2d};
use crate::util::pool::{parallel_region, SharedMut};
use std::sync::Arc;
use std::time::Instant;

pub const GATES: usize = 4;
pub const GATE_ACTS: [Act; GATES] = [Act::Sigmoid, Act::Tanh, Act::Sigmoid, Act::Sigmoid];

/// Shape + blocking for an LSTM cell.
#[derive(Debug, Clone, Copy)]
pub struct LstmConfig {
    /// Mini-batch, input state size, hidden state size, sequence length.
    pub n: usize,
    pub c: usize,
    pub k: usize,
    pub t: usize,
    pub bn: usize,
    pub bc: usize,
    pub bk: usize,
    pub nthreads: usize,
}

impl LstmConfig {
    pub fn new(n: usize, c: usize, k: usize, t: usize) -> LstmConfig {
        use crate::util::num::largest_divisor_le;
        LstmConfig {
            n,
            c,
            k,
            t,
            bn: largest_divisor_le(n, 24),
            bc: largest_divisor_le(c, 64),
            bk: largest_divisor_le(k, 64),
            nthreads: 1,
        }
    }

    /// Set the blocking factors. Each factor must be ≥ 1 and is rounded
    /// *down* to the largest divisor of its dimension (`bn`|N, `bc`|C,
    /// `bk`|K) — non-divisor block sizes are never accepted verbatim.
    pub fn with_blocking(mut self, bn: usize, bc: usize, bk: usize) -> LstmConfig {
        use crate::util::num::largest_divisor_le;
        assert!(bn >= 1 && bc >= 1 && bk >= 1, "block sizes must be >= 1");
        self.bn = largest_divisor_le(self.n, bn);
        self.bc = largest_divisor_le(self.c, bc);
        self.bk = largest_divisor_le(self.k, bk);
        self.validate();
        self
    }

    pub fn with_threads(mut self, t: usize) -> LstmConfig {
        self.nthreads = t;
        self
    }

    fn validate(&self) {
        assert_eq!(self.n % self.bn, 0, "bn must divide N");
        assert_eq!(self.c % self.bc, 0, "bc must divide C");
        assert_eq!(self.k % self.bk, 0, "bk must divide K");
    }

    pub fn nb(&self) -> usize {
        self.n / self.bn
    }
    pub fn cb(&self) -> usize {
        self.c / self.bc
    }
    pub fn kb(&self) -> usize {
        self.k / self.bk
    }

    /// GEMM flops of the full forward pass.
    pub fn fwd_flops(&self) -> f64 {
        self.fwd_flops_t(self.t)
    }

    /// GEMM flops of a forward pass over the first `t_run` steps.
    pub fn fwd_flops_t(&self, t_run: usize) -> f64 {
        let per_step =
            2.0 * GATES as f64 * self.n as f64 * self.k as f64 * (self.c + self.k) as f64;
        per_step * t_run as f64
    }

    /// GEMM flops of backward-by-data + weight-update (2× fwd: dx/dh GEMMs
    /// plus dW/dR GEMMs).
    pub fn bwdupd_flops(&self) -> f64 {
        2.0 * self.fwd_flops()
    }
}

/// Packed weights (blocked layouts). `w`: `[4][Kb][Cb][bc][bk]`,
/// `r`: `[4][Kb][Kb][bk][bk]`, `b`: `[4][K]`.
#[derive(Debug, Clone)]
pub struct LstmWeights {
    pub cfg: LstmConfig,
    pub w: Vec<f32>,
    pub r: Vec<f32>,
    pub b: Vec<f32>,
    /// Seconds spent reformatting plain → blocked (Table 1 accounting).
    pub reformat_secs: f64,
}

impl LstmWeights {
    /// Pack from plain per-gate `K×C` / `K×K` / `K` tensors.
    pub fn pack(cfg: LstmConfig, w_plain: &[&[f32]], r_plain: &[&[f32]], b_plain: &[&[f32]]) -> LstmWeights {
        assert_eq!(w_plain.len(), GATES);
        let t0 = Instant::now();
        let mut w = Vec::with_capacity(GATES * cfg.k * cfg.c);
        let mut r = Vec::with_capacity(GATES * cfg.k * cfg.k);
        let mut b = Vec::with_capacity(GATES * cfg.k);
        for z in 0..GATES {
            assert_eq!(w_plain[z].len(), cfg.k * cfg.c);
            assert_eq!(r_plain[z].len(), cfg.k * cfg.k);
            assert_eq!(b_plain[z].len(), cfg.k);
            w.extend(pack_weights_2d(w_plain[z], cfg.k, cfg.c, cfg.bk, cfg.bc));
            r.extend(pack_weights_2d(r_plain[z], cfg.k, cfg.k, cfg.bk, cfg.bk));
            b.extend_from_slice(b_plain[z]);
        }
        LstmWeights { cfg, w, r, b, reformat_secs: t0.elapsed().as_secs_f64() }
    }

    /// Packed transposes for the backward pass: `wt[4][Cb][Kb][bk][bc]`,
    /// `rt[4][Kb][Kb][bk][bk]` — amortised across all time-steps.
    pub fn transposed(&self) -> LstmWeightsT {
        let cfg = self.cfg;
        let t0 = Instant::now();
        let gw = cfg.k * cfg.c;
        let gr = cfg.k * cfg.k;
        let mut wt = Vec::with_capacity(GATES * gw);
        let mut rt = Vec::with_capacity(GATES * gr);
        for z in 0..GATES {
            wt.extend(transpose_packed_2d(&self.w[z * gw..(z + 1) * gw], cfg.k, cfg.c, cfg.bk, cfg.bc));
            rt.extend(transpose_packed_2d(&self.r[z * gr..(z + 1) * gr], cfg.k, cfg.k, cfg.bk, cfg.bk));
        }
        LstmWeightsT { cfg, wt, rt, reformat_secs: t0.elapsed().as_secs_f64() }
    }
}

/// Transposed packed weights used by backward-by-data.
#[derive(Debug, Clone)]
pub struct LstmWeightsT {
    pub cfg: LstmConfig,
    pub wt: Vec<f32>,
    pub rt: Vec<f32>,
    pub reformat_secs: f64,
}

/// Packed LSTM cell weights behind [`Arc`]s, shared across forward-only
/// execution plans — the serving analogue of
/// [`FcSharedWeights`](crate::primitives::fc::FcSharedWeights) /
/// [`ConvSharedWeights`](crate::primitives::conv::ConvSharedWeights).
/// The packed layouts depend only on the feature blocking `(bc, bk)`,
/// never on the mini-batch or sequence length, so one packed copy backs
/// every batch-bucket plan. Cloning bumps the [`Arc`]s; it never re-packs.
#[derive(Debug, Clone)]
pub struct LstmSharedWeights {
    pub k: usize,
    pub c: usize,
    pub bk: usize,
    pub bc: usize,
    w: Arc<Vec<f32>>, // [4][Kb][Cb][bc][bk]
    r: Arc<Vec<f32>>, // [4][Kb][Kb][bk][bk]
    b: Arc<Vec<f32>>, // [4][K]
}

impl LstmSharedWeights {
    /// Pack canonical unblocked gate weights once for the blocking of
    /// `cfg`. `w_gates` is `[4][K][C]` row-major (gate-major, the artifact
    /// layout), `r_gates` is `[4][K][K]`, `b_gates` is `[4][K]`; gate
    /// order i, g, f, o throughout.
    pub fn pack(cfg: &LstmConfig, w_gates: &[f32], r_gates: &[f32], b_gates: &[f32]) -> LstmSharedWeights {
        let (k, c) = (cfg.k, cfg.c);
        assert_eq!(w_gates.len(), GATES * k * c);
        assert_eq!(r_gates.len(), GATES * k * k);
        assert_eq!(b_gates.len(), GATES * k);
        let mut w = Vec::with_capacity(GATES * k * c);
        let mut r = Vec::with_capacity(GATES * k * k);
        for z in 0..GATES {
            w.extend(pack_weights_2d(&w_gates[z * k * c..(z + 1) * k * c], k, c, cfg.bk, cfg.bc));
            r.extend(pack_weights_2d(&r_gates[z * k * k..(z + 1) * k * k], k, k, cfg.bk, cfg.bk));
        }
        LstmSharedWeights {
            k,
            c,
            bk: cfg.bk,
            bc: cfg.bc,
            w: Arc::new(w),
            r: Arc::new(r),
            b: Arc::new(b_gates.to_vec()),
        }
    }

    pub fn w(&self) -> &[f32] {
        &self.w
    }

    pub fn r(&self) -> &[f32] {
        &self.r
    }

    pub fn b(&self) -> &[f32] {
        &self.b
    }

    /// Canonical unblocked form: (`[4][K][C]` input weights, `[4][K][K]`
    /// recurrent weights, `[4][K]` biases) — the exact inverse of
    /// [`LstmSharedWeights::pack`].
    pub fn to_plain(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (k, c) = (self.k, self.c);
        let gw = k * c;
        let gr = k * k;
        let mut w = Vec::with_capacity(GATES * gw);
        let mut r = Vec::with_capacity(GATES * gr);
        for z in 0..GATES {
            w.extend(unpack_weights_2d(&self.w[z * gw..(z + 1) * gw], k, c, self.bk, self.bc));
            r.extend(unpack_weights_2d(&self.r[z * gr..(z + 1) * gr], k, k, self.bk, self.bk));
        }
        (w, r, self.b.to_vec())
    }

    /// Can an execution plan with this config run against these weights?
    /// Shape and feature blocking must agree (`bn` and `t` are free —
    /// that is what lets one packed copy back every batch bucket).
    pub fn matches(&self, cfg: &LstmConfig) -> bool {
        self.k == cfg.k && self.c == cfg.c && self.bk == cfg.bk && self.bc == cfg.bc
    }

    /// Stable identity of the underlying packed-weight allocation; two
    /// clones share it. Used by tests to assert weights are allocated
    /// exactly once however many bucket plans exist.
    pub fn alloc_id(&self) -> usize {
        Arc::as_ptr(&self.w) as usize
    }
}

/// Forward workspace: gate activations and states kept for training.
/// `h`/`s` have T+1 steps with step 0 = the initial state. (`Default`
/// gives empty buffers; the serving scratch resizes them per bucket.)
#[derive(Debug, Clone, Default)]
pub struct LstmWorkspace {
    pub gates: Vec<f32>, // [4][T][N][K], post-activation
    pub h: Vec<f32>,     // [T+1][N][K]
    pub s: Vec<f32>,     // [T+1][N][K]
}

impl LstmWorkspace {
    pub fn new(cfg: &LstmConfig) -> LstmWorkspace {
        let nk = cfg.n * cfg.k;
        LstmWorkspace {
            gates: vec![0.0; GATES * cfg.t * nk],
            h: vec![0.0; (cfg.t + 1) * nk],
            s: vec![0.0; (cfg.t + 1) * nk],
        }
    }

    /// Output sequence h[1..=T] as (t, N·K) slices.
    pub fn h_t(&self, cfg: &LstmConfig, t: usize) -> &[f32] {
        let nk = cfg.n * cfg.k;
        &self.h[(t + 1) * nk..(t + 2) * nk]
    }
}

/// Gradients produced by the backward/update pass.
#[derive(Debug, Clone)]
pub struct LstmGrads {
    pub dx: Vec<f32>, // [T][N][C]
    pub dw: Vec<f32>, // [4][Kb][Cb][bc][bk]
    pub dr: Vec<f32>, // [4][Kb][Kb][bk][bk]
    pub db: Vec<f32>, // [4][K]
}

/// Timing breakdown of a pass (Table 1 reproduction).
#[derive(Debug, Clone, Copy, Default)]
pub struct LstmBreakdown {
    pub gemm_secs: f64,
    pub eltwise_secs: f64,
    pub reformat_secs: f64,
}

impl LstmBreakdown {
    pub fn total(&self) -> f64 {
        self.gemm_secs + self.eltwise_secs + self.reformat_secs
    }
}

/// The BRGEMM-based LSTM cell.
pub struct LstmPrimitive {
    pub cfg: LstmConfig,
    kern_wx: BrgemmKernel,            // W·x, β=0
    kern_rh: [BrgemmKernel; GATES],   // R·h, β=1, fused bias+gate-act
    kern_bwd_x: BrgemmKernel,         // dz·Wᵀ → dx
    kern_bwd_h: BrgemmKernel,         // dz·Rᵀ → dh
    kern_upd_w: BrgemmKernel,         // xᵀ·dz → dW
    kern_upd_r: BrgemmKernel,         // hᵀ·dz → dR
    /// Profiler slot — None (one branch per pass) unless a profiler was
    /// installed at construction time.
    tele: Option<Arc<PrimSlot>>,
}

impl LstmPrimitive {
    pub fn new(cfg: LstmConfig) -> LstmPrimitive {
        cfg.validate();
        let wx = BrgemmKernel::new(BrgemmDesc {
            m: cfg.bn,
            n: cfg.bk,
            k: cfg.bc,
            lda: cfg.c,
            ldb: cfg.bk,
            ldc: cfg.k,
            a_kstride: 1,
            alpha: 1.0,
            beta: 0.0,
        });
        let rh_desc = BrgemmDesc {
            m: cfg.bn,
            n: cfg.bk,
            k: cfg.bk,
            lda: cfg.k,
            ldb: cfg.bk,
            ldc: cfg.k,
            a_kstride: 1,
            alpha: 1.0,
            beta: 1.0,
        };
        let rh = GATE_ACTS
            .map(|act| BrgemmKernel::new(rh_desc).with_epilogue(Epilogue::BiasAct(act)));
        // dx_blk[bn×bc] = Σ_{z,kb} dz_blk[bn×bk]·Wᵀ_blk[bk×bc]
        let bwd_x = BrgemmKernel::new(BrgemmDesc {
            m: cfg.bn,
            n: cfg.bc,
            k: cfg.bk,
            lda: cfg.k,
            ldb: cfg.bc,
            ldc: cfg.c,
            a_kstride: 1,
            alpha: 1.0,
            beta: 0.0,
        });
        // dh_blk[bn×bk2] = Σ_{z,kb} dz_blk[bn×bk]·Rᵀ_blk[bk×bk2], β=1
        // (accumulates into dh which already holds the upstream gradient).
        let bwd_h = BrgemmKernel::new(BrgemmDesc {
            m: cfg.bn,
            n: cfg.bk,
            k: cfg.bk,
            lda: cfg.k,
            ldb: cfg.bk,
            ldc: cfg.k,
            a_kstride: 1,
            alpha: 1.0,
            beta: 1.0,
        });
        // dW_blk[bc×bk] = Σ_{t,nb} xᵀ_blk[bc×bn]·dz_blk[bn×bk]; x is
        // physically transposed once per pass into xT[T][C][N] so the
        // accumulation chain reads contiguous rows (perf-pass iteration 4:
        // the in-place a_kstride=C read walked one element per cache line
        // at large C — the paper's "bwd and upd passes require additional
        // activation tensor transposes" is the same trade, counted as
        // reformat time in Table 1).
        let upd_w = BrgemmKernel::new(BrgemmDesc {
            m: cfg.bc,
            n: cfg.bk,
            k: cfg.bn,
            lda: cfg.n,
            ldb: cfg.k,
            ldc: cfg.bk,
            a_kstride: 1,
            alpha: 1.0,
            beta: 0.0,
        });
        let upd_r = BrgemmKernel::new(BrgemmDesc {
            m: cfg.bk,
            n: cfg.bk,
            k: cfg.bn,
            lda: cfg.n,
            ldb: cfg.k,
            ldc: cfg.bk,
            a_kstride: 1,
            alpha: 1.0,
            beta: 0.0,
        });
        let tele =
            telemetry::register("lstm", format!("n{} c{} k{} t{}", cfg.n, cfg.c, cfg.k, cfg.t));
        LstmPrimitive {
            cfg,
            kern_wx: wx,
            kern_rh: rh,
            kern_bwd_x: bwd_x,
            kern_bwd_h: bwd_h,
            kern_upd_w: upd_w,
            kern_upd_r: upd_r,
            tele,
        }
    }

    /// Bytes of the pass working set (x, gates, h, s, weights, biases —
    /// f32); the backward/update passes touch gradient tensors of the same
    /// shapes, so one estimate serves every pass's roofline denominator.
    fn bytes_moved(&self) -> u64 {
        let c = &self.cfg;
        4 * (c.t * c.n * c.c
            + GATES * c.t * c.n * c.k
            + 2 * (c.t + 1) * c.n * c.k
            + GATES * c.k * (c.c + c.k)
            + GATES * c.k) as u64
    }

    /// Like [`LstmPrimitive::new`], but first consults the persistent
    /// tuning cache ((N, C, K, T) + ISA + thread count key — the sequence
    /// length participates in the key, so two workloads differing only in
    /// `t` never share a cached blocking) and, on a hit, applies the
    /// cached winning blocking. On a miss the config is used as-is —
    /// populate the cache with the `tune` CLI subcommand or
    /// [`crate::autotune::tuner::tune_lstm_cached`].
    pub fn tuned(cfg: LstmConfig) -> LstmPrimitive {
        LstmPrimitive::new(crate::autotune::tuned_lstm_config(cfg))
    }

    /// Forward propagation (Algorithm 2). `x` is `[T][N][C]`; initial state
    /// `h0`/`s0` may be `None` (zeros). Fills `ws`; returns the timing
    /// breakdown used by the Table 1 bench.
    pub fn forward(
        &self,
        x: &[f32],
        h0: Option<&[f32]>,
        s0: Option<&[f32]>,
        weights: &LstmWeights,
        ws: &mut LstmWorkspace,
    ) -> LstmBreakdown {
        self.forward_t(x, h0, s0, weights, ws, self.cfg.t)
    }

    /// [`LstmPrimitive::forward`] over only the first `t_run <= cfg.t`
    /// time-steps — prefix execution: the same packed weights, kernels and
    /// full-capacity workspace serve any runtime sequence length up to the
    /// config's `t`, so one tuned config covers a whole length bucket.
    /// `x` must hold at least `t_run` steps (`[t_run][N][C]` prefix);
    /// workspace entries past `t_run` are left untouched.
    pub fn forward_t(
        &self,
        x: &[f32],
        h0: Option<&[f32]>,
        s0: Option<&[f32]>,
        weights: &LstmWeights,
        ws: &mut LstmWorkspace,
        t_run: usize,
    ) -> LstmBreakdown {
        self.forward_parts(
            x,
            h0,
            s0,
            &weights.w,
            &weights.r,
            &weights.b,
            weights.reformat_secs,
            ws,
            t_run,
        )
    }

    /// [`LstmPrimitive::forward`] against [`Arc`]-shared packed weights —
    /// the serving path: many bucket plans, one packed copy.
    pub fn forward_shared(
        &self,
        x: &[f32],
        h0: Option<&[f32]>,
        s0: Option<&[f32]>,
        weights: &LstmSharedWeights,
        ws: &mut LstmWorkspace,
    ) -> LstmBreakdown {
        self.forward_shared_t(x, h0, s0, weights, ws, self.cfg.t)
    }

    /// [`LstmPrimitive::forward_shared`] over only the first `t_run` steps
    /// (see [`LstmPrimitive::forward_t`]) — what a (length bucket × batch
    /// bucket) serving plan executes.
    pub fn forward_shared_t(
        &self,
        x: &[f32],
        h0: Option<&[f32]>,
        s0: Option<&[f32]>,
        weights: &LstmSharedWeights,
        ws: &mut LstmWorkspace,
        t_run: usize,
    ) -> LstmBreakdown {
        assert!(
            weights.matches(&self.cfg),
            "shared weights ({}x{} bk{} bc{}) do not match plan ({}x{} bk{} bc{})",
            weights.k, weights.c, weights.bk, weights.bc,
            self.cfg.k, self.cfg.c, self.cfg.bk, self.cfg.bc
        );
        self.forward_parts(x, h0, s0, weights.w(), weights.r(), weights.b(), 0.0, ws, t_run)
    }

    /// The forward body over raw packed-weight slices (`w`
    /// `[4][Kb][Cb][bc][bk]`, `r` `[4][Kb][Kb][bk][bk]`, `b` `[4][K]`);
    /// `reformat_secs` is charged to the returned breakdown.
    #[allow(clippy::too_many_arguments)]
    fn forward_parts(
        &self,
        x: &[f32],
        h0: Option<&[f32]>,
        s0: Option<&[f32]>,
        w: &[f32],
        r: &[f32],
        b: &[f32],
        reformat_secs: f64,
        ws: &mut LstmWorkspace,
        t_run: usize,
    ) -> LstmBreakdown {
        let cfg = &self.cfg;
        assert!(
            t_run >= 1 && t_run <= cfg.t,
            "t_run {} must be in 1..={} (the config's capacity)",
            t_run,
            cfg.t
        );
        assert!(x.len() >= t_run * cfg.n * cfg.c, "x holds at least t_run steps");
        let nk = cfg.n * cfg.k;
        let tnk = cfg.t * nk;
        assert_eq!(ws.gates.len(), GATES * tnk, "workspace gates sized for this config");
        assert_eq!(ws.h.len(), (cfg.t + 1) * nk, "workspace h sized for this config");
        assert_eq!(ws.s.len(), (cfg.t + 1) * nk, "workspace s sized for this config");
        if let Some(h0) = h0 {
            ws.h[..nk].copy_from_slice(h0);
        } else {
            ws.h[..nk].fill(0.0);
        }
        if let Some(s0) = s0 {
            ws.s[..nk].copy_from_slice(s0);
        } else {
            ws.s[..nk].fill(0.0);
        }

        let tele0 = self.tele.as_ref().map(|_| Instant::now());
        let (nb, cb, kb) = (cfg.nb(), cfg.cb(), cfg.kb());
        let part = Partition2d::auto(nb, kb, cfg.nthreads, false);
        let gw = cfg.k * cfg.c; // per-gate packed W size
        let gr = cfg.k * cfg.k;
        let wblk = cfg.bc * cfg.bk;
        let rblk = cfg.bk * cfg.bk;
        let mut bd = LstmBreakdown { reformat_secs, ..Default::default() };

        for t in 0..t_run {
            let t0 = Instant::now();
            let gates_shared = &SharedMut::new(&mut ws.gates);
            // split h/s into (past, current) so threads can read h[t], s[t]
            // while writing h[t+1], s[t+1].
            let (h_past, h_cur) = ws.h.split_at_mut((t + 1) * nk);
            let (s_past, s_cur) = ws.s.split_at_mut((t + 1) * nk);
            let h_prev = &h_past[t * nk..];
            let s_prev = &s_past[t * nk..];
            let h_cur = &SharedMut::new(&mut h_cur[..nk]);
            let s_cur = &SharedMut::new(&mut s_cur[..nk]);
            let eltwise_ns = std::sync::atomic::AtomicU64::new(0);
            parallel_region(cfg.nthreads, |tid| {
                let mut a_offs = vec![0usize; cb.max(kb)];
                let mut b_offs = vec![0usize; cb.max(kb)];
                for (inb, ikb) in part.tasks(tid) {
                    let in0 = inb * cfg.bn;
                    let ik0 = ikb * cfg.bk;
                    for z in 0..GATES {
                        let g_off = z * tnk + t * nk + in0 * cfg.k + ik0;
                        // SAFETY: gate blocks are disjoint per (z, task).
                        let g_len = (cfg.bn - 1) * cfg.k + cfg.bk;
                        let gate_blk = unsafe { gates_shared.slice(g_off, g_len) };
                        // W_z · x_t  (batch over input-feature blocks)
                        for icb in 0..cb {
                            a_offs[icb] = t * cfg.n * cfg.c + in0 * cfg.c + icb * cfg.bc;
                            b_offs[icb] = z * gw + (ikb * cb + icb) * wblk;
                        }
                        self.kern_wx.execute_offs(
                            x,
                            &a_offs[..cb],
                            w,
                            &b_offs[..cb],
                            gate_blk,
                            None,
                        );
                        // + R_z · h_{t-1}, bias + activation fused.
                        for ikb2 in 0..kb {
                            a_offs[ikb2] = in0 * cfg.k + ikb2 * cfg.bk;
                            b_offs[ikb2] = z * gr + (ikb * kb + ikb2) * rblk;
                        }
                        self.kern_rh[z].execute_offs(
                            h_prev,
                            &a_offs[..kb],
                            r,
                            &b_offs[..kb],
                            gate_blk,
                            Some(&b[z * cfg.k + ik0..z * cfg.k + ik0 + cfg.bk]),
                        );
                    }
                    // State recurrences on the hot block (Eq. 5-6).
                    let e0 = Instant::now();
                    let base = t * nk + in0 * cfg.k + ik0;
                    let blk_len = (cfg.bn - 1) * cfg.k + cfg.bk;
                    // SAFETY: re-borrow of the gate blocks this task just
                    // wrote (disjoint across tasks), now read-only.
                    let i_blk = &*unsafe { gates_shared.slice(base, blk_len) };
                    let g_blk = &*unsafe { gates_shared.slice(tnk + base, blk_len) };
                    let f_blk = &*unsafe { gates_shared.slice(2 * tnk + base, blk_len) };
                    let o_blk = &*unsafe { gates_shared.slice(3 * tnk + base, blk_len) };
                    let off = in0 * cfg.k + ik0;
                    let s_out = unsafe { s_cur.slice(off, blk_len) };
                    let h_out = unsafe { h_cur.slice(off, blk_len) };
                    for r in 0..cfg.bn {
                        for j in 0..cfg.bk {
                            let idx = r * cfg.k + j;
                            let sv = f_blk[idx] * s_prev[off + idx] + i_blk[idx] * g_blk[idx];
                            s_out[idx] = sv;
                            h_out[idx] = o_blk[idx] * sv.tanh();
                        }
                    }
                    eltwise_ns.fetch_add(
                        e0.elapsed().as_nanos() as u64,
                        std::sync::atomic::Ordering::Relaxed,
                    );
                }
            });
            let el = eltwise_ns.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e9
                / cfg.nthreads as f64;
            bd.eltwise_secs += el;
            bd.gemm_secs += t0.elapsed().as_secs_f64() - el;
        }
        if let (Some(slot), Some(tele0)) = (self.tele.as_ref(), tele0) {
            // Two BRGEMM calls (W·x, R·h) per gate per (nb × kb) block per
            // executed step.
            let calls = (t_run * nb * kb * GATES * 2) as u64;
            slot.record(
                Pass::Fwd,
                calls,
                cfg.fwd_flops_t(t_run),
                self.bytes_moved(),
                tele0.elapsed(),
            );
        }
        bd
    }

    /// Backward-by-data + weight-update pass. `dh_out` is the upstream
    /// gradient of the output sequence (`[T][N][K]`); `x`/`ws` are from the
    /// forward pass. One fused sweep computes dx, dW, dR, db (the paper
    /// reports "bwd & upd" together in Table 1 and Fig. 6).
    pub fn backward(
        &self,
        x: &[f32],
        dh_out: &[f32],
        weights_t: &LstmWeightsT,
        ws: &LstmWorkspace,
    ) -> (LstmGrads, LstmBreakdown) {
        self.backward_t(x, dh_out, weights_t, ws, self.cfg.t)
    }

    /// [`LstmPrimitive::backward`] over only the first `t_run <= cfg.t`
    /// steps — the BPTT mirror of [`LstmPrimitive::forward_t`]: `dh_out`
    /// is `[t_run][N][K]`, the returned `dx` is `[t_run][N][C]`, and the
    /// weight gradients accumulate over exactly the executed prefix.
    pub fn backward_t(
        &self,
        x: &[f32],
        dh_out: &[f32],
        weights_t: &LstmWeightsT,
        ws: &LstmWorkspace,
        t_run: usize,
    ) -> (LstmGrads, LstmBreakdown) {
        let cfg = &self.cfg;
        assert!(
            t_run >= 1 && t_run <= cfg.t,
            "t_run {} must be in 1..={} (the config's capacity)",
            t_run,
            cfg.t
        );
        let nk = cfg.n * cfg.k;
        let tnk = cfg.t * nk;
        assert_eq!(dh_out.len(), t_run * nk);
        assert!(x.len() >= t_run * cfg.n * cfg.c, "x holds at least t_run steps");
        let tele0 = self.tele.as_ref().map(|_| Instant::now());
        let (nb, cb, kb) = (cfg.nb(), cfg.cb(), cfg.kb());
        let mut bd =
            LstmBreakdown { reformat_secs: weights_t.reformat_secs, ..Default::default() };

        // Pre-activation gate gradients for every t (filled back-to-front).
        // Full-capacity strides (tnk) so the gate offsets match the forward
        // workspace layout; only the first t_run steps are ever touched.
        let mut dz = vec![0.0f32; GATES * tnk];
        let mut dh = vec![0.0f32; nk]; // recurrent dh carry
        let mut ds = vec![0.0f32; nk]; // recurrent ds carry
        let mut dx = vec![0.0f32; t_run * cfg.n * cfg.c];

        let gw = cfg.k * cfg.c;
        let gr = cfg.k * cfg.k;
        let wblk = cfg.bc * cfg.bk;
        let rblk = cfg.bk * cfg.bk;

        for t in (0..t_run).rev() {
            // --- eltwise: gate gradients (per element) ---
            let e0 = Instant::now();
            {
                let i_t = &ws.gates[t * nk..t * nk + nk];
                let g_t = &ws.gates[tnk + t * nk..tnk + t * nk + nk];
                let f_t = &ws.gates[2 * tnk + t * nk..2 * tnk + t * nk + nk];
                let o_t = &ws.gates[3 * tnk + t * nk..3 * tnk + t * nk + nk];
                let s_t = &ws.s[(t + 1) * nk..(t + 2) * nk];
                let s_prev = &ws.s[t * nk..(t + 1) * nk];
                let dh_up = &dh_out[t * nk..(t + 1) * nk];
                for idx in 0..nk {
                    let dht = dh_up[idx] + dh[idx];
                    let tanh_s = s_t[idx].tanh();
                    let dot = dht * tanh_s;
                    let dst = dht * o_t[idx] * (1.0 - tanh_s * tanh_s) + ds[idx];
                    let dit = dst * g_t[idx];
                    let dgt = dst * i_t[idx];
                    let dft = dst * s_prev[idx];
                    ds[idx] = dst * f_t[idx]; // carry to t-1
                    // pre-activation chain rule
                    dz[t * nk + idx] = dit * i_t[idx] * (1.0 - i_t[idx]);
                    dz[tnk + t * nk + idx] = dgt * (1.0 - g_t[idx] * g_t[idx]);
                    dz[2 * tnk + t * nk + idx] = dft * f_t[idx] * (1.0 - f_t[idx]);
                    dz[3 * tnk + t * nk + idx] = dot * o_t[idx] * (1.0 - o_t[idx]);
                }
            }
            bd.eltwise_secs += e0.elapsed().as_secs_f64();

            // --- GEMMs: dh_{t-1} = Σ_z dz_z·R_zᵀ ; dx_t = Σ_z dz_z·W_zᵀ ---
            let g0 = Instant::now();
            dh.fill(0.0);
            {
                let dh_shared = &SharedMut::new(&mut dh);
                let part = Partition2d::auto(nb, kb, cfg.nthreads, false);
                parallel_region(cfg.nthreads, |tid| {
                    let batch = GATES * kb;
                    let mut a_offs = vec![0usize; batch];
                    let mut b_offs = vec![0usize; batch];
                    for (inb, ikb2) in part.tasks(tid) {
                        let in0 = inb * cfg.bn;
                        let mut bi = 0;
                        for z in 0..GATES {
                            for ikb in 0..kb {
                                a_offs[bi] = z * tnk + t * nk + in0 * cfg.k + ikb * cfg.bk;
                                b_offs[bi] = z * gr + (ikb * kb + ikb2) * rblk;
                                bi += 1;
                            }
                        }
                        let off = in0 * cfg.k + ikb2 * cfg.bk;
                        let len = (cfg.bn - 1) * cfg.k + cfg.bk;
                        let out = unsafe { dh_shared.slice(off, len) };
                        self.kern_bwd_h.execute_offs(
                            &dz,
                            &a_offs,
                            &weights_t.rt,
                            &b_offs,
                            out,
                            None,
                        );
                    }
                });
            }
            {
                let dx_shared = &SharedMut::new(&mut dx);
                let part = Partition2d::auto(nb, cb, cfg.nthreads, false);
                parallel_region(cfg.nthreads, |tid| {
                    let batch = GATES * kb;
                    let mut a_offs = vec![0usize; batch];
                    let mut b_offs = vec![0usize; batch];
                    for (inb, icb) in part.tasks(tid) {
                        let in0 = inb * cfg.bn;
                        let mut bi = 0;
                        for z in 0..GATES {
                            for ikb in 0..kb {
                                a_offs[bi] = z * tnk + t * nk + in0 * cfg.k + ikb * cfg.bk;
                                b_offs[bi] = z * gw + (icb * kb + ikb) * wblk;
                                bi += 1;
                            }
                        }
                        let off = t * cfg.n * cfg.c + in0 * cfg.c + icb * cfg.bc;
                        let len = (cfg.bn - 1) * cfg.c + cfg.bc;
                        let out = unsafe { dx_shared.slice(off, len) };
                        self.kern_bwd_x.execute_offs(
                            &dz,
                            &a_offs,
                            &weights_t.wt,
                            &b_offs,
                            out,
                            None,
                        );
                    }
                });
            }
            bd.gemm_secs += g0.elapsed().as_secs_f64();
        }
        let tele1 = if let (Some(slot), Some(tele0)) = (self.tele.as_ref(), tele0) {
            // Per step: one dh chain per (nb × kb) block + one dx chain per
            // (nb × cb) block; GEMM work equals one forward pass.
            let calls = (t_run * nb * (kb + cb)) as u64;
            slot.record(
                Pass::Bwd,
                calls,
                cfg.fwd_flops_t(t_run),
                self.bytes_moved(),
                tele0.elapsed(),
            );
            Some(Instant::now())
        } else {
            None
        };

        // --- weight update: batch over (t, nb) in a single BRGEMM chain ---
        // Physical activation transposes (reformat; see kernel docs above).
        let r0 = Instant::now();
        let mut xt = vec![0.0f32; t_run * cfg.c * cfg.n];
        for t in 0..t_run {
            let src = &x[t * cfg.n * cfg.c..(t + 1) * cfg.n * cfg.c];
            let dst = &mut xt[t * cfg.c * cfg.n..(t + 1) * cfg.c * cfg.n];
            for ni in 0..cfg.n {
                for ci in 0..cfg.c {
                    dst[ci * cfg.n + ni] = src[ni * cfg.c + ci];
                }
            }
        }
        // h_{t-1} sequence (steps 0..t_run of ws.h), transposed per step.
        let mut ht = vec![0.0f32; t_run * cfg.k * cfg.n];
        for t in 0..t_run {
            let src = &ws.h[t * nk..(t + 1) * nk];
            let dst = &mut ht[t * cfg.k * cfg.n..(t + 1) * cfg.k * cfg.n];
            for ni in 0..cfg.n {
                for ki in 0..cfg.k {
                    dst[ki * cfg.n + ni] = src[ni * cfg.k + ki];
                }
            }
        }
        bd.reformat_secs += r0.elapsed().as_secs_f64();

        let g0 = Instant::now();
        let mut dw = vec![0.0f32; GATES * cfg.k * cfg.c];
        let mut dr = vec![0.0f32; GATES * cfg.k * cfg.k];
        let mut db = vec![0.0f32; GATES * cfg.k];
        {
            // dW[z][ikb][icb]: tasks over (z·Kb × Cb)
            let dw_shared = &SharedMut::new(&mut dw);
            let part = Partition2d::new(GATES * kb, cb, cfg.nthreads, Strategy::Flat);
            parallel_region(cfg.nthreads, |tid| {
                let batch = t_run * nb;
                let mut a_offs = vec![0usize; batch];
                let mut b_offs = vec![0usize; batch];
                for (zikb, icb) in part.tasks(tid) {
                    let (z, ikb) = (zikb / kb, zikb % kb);
                    let mut bi = 0;
                    for t in 0..t_run {
                        for inb in 0..nb {
                            // xT[t][icb*bc + :][inb*bn + :]
                            a_offs[bi] =
                                t * cfg.c * cfg.n + icb * cfg.bc * cfg.n + inb * cfg.bn;
                            b_offs[bi] =
                                z * tnk + t * nk + inb * cfg.bn * cfg.k + ikb * cfg.bk;
                            bi += 1;
                        }
                    }
                    let off = z * gw + (ikb * cb + icb) * wblk;
                    let out = unsafe { dw_shared.slice(off, wblk) };
                    self.kern_upd_w.execute_offs(&xt, &a_offs, &dz, &b_offs, out, None);
                }
            });
            // dR[z][ikb][ikb2]: A = h_{t-1}ᵀ (= ws.h step t), B = dz_t
            let dr_shared = &SharedMut::new(&mut dr);
            let part = Partition2d::new(GATES * kb, kb, cfg.nthreads, Strategy::Flat);
            parallel_region(cfg.nthreads, |tid| {
                let batch = t_run * nb;
                let mut a_offs = vec![0usize; batch];
                let mut b_offs = vec![0usize; batch];
                for (zikb, ikb2) in part.tasks(tid) {
                    let (z, ikb) = (zikb / kb, zikb % kb);
                    let mut bi = 0;
                    for t in 0..t_run {
                        for inb in 0..nb {
                            // hT[t][ikb2*bk + :][inb*bn + :]  (h step t = h_{t-1})
                            a_offs[bi] =
                                t * cfg.k * cfg.n + ikb2 * cfg.bk * cfg.n + inb * cfg.bn;
                            b_offs[bi] =
                                z * tnk + t * nk + inb * cfg.bn * cfg.k + ikb * cfg.bk;
                            bi += 1;
                        }
                    }
                    let off = z * gr + (ikb * kb + ikb2) * rblk;
                    let out = unsafe { dr_shared.slice(off, rblk) };
                    self.kern_upd_r.execute_offs(&ht, &a_offs, &dz, &b_offs, out, None);
                }
            });
        }
        // db: plain reduction.
        for z in 0..GATES {
            for t in 0..t_run {
                for n in 0..cfg.n {
                    let row = z * tnk + t * nk + n * cfg.k;
                    for j in 0..cfg.k {
                        db[z * cfg.k + j] += dz[row + j];
                    }
                }
            }
        }
        bd.gemm_secs += g0.elapsed().as_secs_f64();
        if let (Some(slot), Some(tele1)) = (self.tele.as_ref(), tele1) {
            // One (t_run·Nb)-long chain per dW block (4·Kb·Cb) + per dR
            // block (4·Kb·Kb); GEMM work again equals one forward pass.
            let calls = (GATES * kb * (cb + kb)) as u64;
            slot.record(
                Pass::Upd,
                calls,
                cfg.fwd_flops_t(t_run),
                self.bytes_moved(),
                tele1.elapsed(),
            );
        }

        (LstmGrads { dx, dw, dr, db }, bd)
    }
}

/// Coarse-grained baseline cell (§3.1.1): per time-step, two large GEMMs on
/// stacked weights (`[4K×C]`, `[4K×K]`) followed by a full-tensor
/// element-wise sweep — the formulation whose eltwise stage is exposed as a
/// bandwidth-bound kernel on cold outputs.
pub struct LstmLargeGemm {
    pub cfg: LstmConfig,
    /// Stacked plain weights: wᵀ `[C][4K]`, rᵀ `[K][4K]` (pre-transposed
    /// once so each step is a pure `N×C · C×4K` GEMM).
    wt: Vec<f32>,
    rt: Vec<f32>,
    b: Vec<f32>, // [4K]
}

impl LstmLargeGemm {
    pub fn new(cfg: LstmConfig, w_plain: &[&[f32]], r_plain: &[&[f32]], b_plain: &[&[f32]]) -> LstmLargeGemm {
        let (c, k) = (cfg.c, cfg.k);
        let mut wt = vec![0.0f32; c * 4 * k];
        let mut rt = vec![0.0f32; k * 4 * k];
        let mut b = vec![0.0f32; 4 * k];
        for z in 0..GATES {
            for kk in 0..k {
                for cc in 0..c {
                    wt[cc * 4 * k + z * k + kk] = w_plain[z][kk * c + cc];
                }
                for cc in 0..k {
                    rt[cc * 4 * k + z * k + kk] = r_plain[z][kk * k + cc];
                }
            }
            b[z * k..(z + 1) * k].copy_from_slice(b_plain[z]);
        }
        LstmLargeGemm { cfg, wt, rt, b }
    }

    /// Forward pass; returns `(h, s)` sequences (`[T+1][N][K]`).
    pub fn forward(&self, x: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let cfg = &self.cfg;
        let (n, c, k) = (cfg.n, cfg.c, cfg.k);
        let nk = n * k;
        let mut h = vec![0.0f32; (cfg.t + 1) * nk];
        let mut s = vec![0.0f32; (cfg.t + 1) * nk];
        let mut z = vec![0.0f32; n * 4 * k];
        let gemm_x = Gemm::dense(n, 4 * k, c);
        let gemm_h = Gemm::dense(n, 4 * k, k).with_alpha_beta(1.0, 1.0);
        for t in 0..cfg.t {
            gemm_x.execute(&x[t * n * c..(t + 1) * n * c], &self.wt, &mut z);
            let h_prev = h[t * nk..(t + 1) * nk].to_vec();
            gemm_h.execute(&h_prev, &self.rt, &mut z);
            // Exposed element-wise sweep over the whole cold Z tensor.
            for ni in 0..n {
                for j in 0..k {
                    let iv = Act::Sigmoid.apply(z[ni * 4 * k + j] + self.b[j]);
                    let gv = Act::Tanh.apply(z[ni * 4 * k + k + j] + self.b[k + j]);
                    let fv = Act::Sigmoid.apply(z[ni * 4 * k + 2 * k + j] + self.b[2 * k + j]);
                    let ov = Act::Sigmoid.apply(z[ni * 4 * k + 3 * k + j] + self.b[3 * k + j]);
                    let sv = fv * s[t * nk + ni * k + j] + iv * gv;
                    s[(t + 1) * nk + ni * k + j] = sv;
                    h[(t + 1) * nk + ni * k + j] = ov * sv.tanh();
                }
            }
        }
        (h, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::primitives::naive;
    use crate::util::rng::Rng;

    struct Setup {
        cfg: LstmConfig,
        x: Vec<f32>,
        w: Vec<Vec<f32>>,
        r: Vec<Vec<f32>>,
        b: Vec<Vec<f32>>,
    }

    fn setup(n: usize, c: usize, k: usize, t: usize, seed: u64) -> Setup {
        let mut rng = Rng::new(seed);
        let cfg = LstmConfig::new(n, c, k, t);
        Setup {
            cfg,
            x: rng.vec_f32(t * n * c, -1.0, 1.0),
            w: (0..GATES).map(|_| rng.vec_f32(k * c, -0.3, 0.3)).collect(),
            r: (0..GATES).map(|_| rng.vec_f32(k * k, -0.3, 0.3)).collect(),
            b: (0..GATES).map(|_| rng.vec_f32(k, -0.1, 0.1)).collect(),
        }
    }

    fn naive_sequence(s: &Setup) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<[Vec<f32>; 4]>) {
        let cfg = &s.cfg;
        let (n, c, k) = (cfg.n, cfg.c, cfg.k);
        let w: [&[f32]; 4] = [&s.w[0], &s.w[1], &s.w[2], &s.w[3]];
        let r: [&[f32]; 4] = [&s.r[0], &s.r[1], &s.r[2], &s.r[3]];
        let b: [&[f32]; 4] = [&s.b[0], &s.b[1], &s.b[2], &s.b[3]];
        let mut h = vec![vec![0.0f32; n * k]];
        let mut st = vec![vec![0.0f32; n * k]];
        let mut gates = Vec::new();
        for t in 0..cfg.t {
            let (i, g, f, o, s_t, h_t) = naive::lstm_step(
                n, c, k,
                &s.x[t * n * c..(t + 1) * n * c],
                h.last().unwrap(),
                st.last().unwrap(),
                &w, &r, &b,
            );
            gates.push([i, g, f, o]);
            h.push(h_t);
            st.push(s_t);
        }
        (h, st, gates)
    }

    #[test]
    fn forward_matches_naive() {
        for &(n, c, k, t, threads) in &[(4, 8, 8, 3, 1), (6, 16, 24, 5, 2), (8, 32, 16, 2, 1)] {
            let s = setup(n, c, k, t, 21);
            let cfg = s.cfg.with_threads(threads);
            let prim = LstmPrimitive::new(cfg);
            let wref: Vec<&[f32]> = s.w.iter().map(|v| v.as_slice()).collect();
            let rref: Vec<&[f32]> = s.r.iter().map(|v| v.as_slice()).collect();
            let bref: Vec<&[f32]> = s.b.iter().map(|v| v.as_slice()).collect();
            let weights = LstmWeights::pack(cfg, &wref, &rref, &bref);
            let mut ws = LstmWorkspace::new(&cfg);
            prim.forward(&s.x, None, None, &weights, &mut ws);
            let (h_want, s_want, _) = naive_sequence(&s);
            for tt in 0..t {
                let h_got = ws.h_t(&cfg, tt);
                for i in 0..n * k {
                    assert!(
                        (h_got[i] - h_want[tt + 1][i]).abs() < 1e-4,
                        "h[t={}][{}]: {} vs {} (n{} c{} k{} threads{})",
                        tt, i, h_got[i], h_want[tt + 1][i], n, c, k, threads
                    );
                }
                let s_got = &ws.s[(tt + 1) * n * k..(tt + 2) * n * k];
                for i in 0..n * k {
                    assert!((s_got[i] - s_want[tt + 1][i]).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn large_gemm_baseline_matches_naive() {
        let s = setup(5, 12, 8, 4, 33);
        let wref: Vec<&[f32]> = s.w.iter().map(|v| v.as_slice()).collect();
        let rref: Vec<&[f32]> = s.r.iter().map(|v| v.as_slice()).collect();
        let bref: Vec<&[f32]> = s.b.iter().map(|v| v.as_slice()).collect();
        let cell = LstmLargeGemm::new(s.cfg, &wref, &rref, &bref);
        let (h, _) = cell.forward(&s.x);
        let (h_want, _, _) = naive_sequence(&s);
        let nk = s.cfg.n * s.cfg.k;
        for t in 0..s.cfg.t {
            for i in 0..nk {
                assert!(
                    (h[(t + 1) * nk + i] - h_want[t + 1][i]).abs() < 1e-4,
                    "t={} i={}", t, i
                );
            }
        }
    }

    /// Full-sequence gradient check of the fused backward pass against
    /// central differences of the scalar loss  L = Σ_t Σ_{n,k} h_t.
    #[test]
    fn backward_gradcheck() {
        let s = setup(2, 4, 4, 3, 55);
        let cfg = s.cfg;
        let prim = LstmPrimitive::new(cfg);
        let wref: Vec<&[f32]> = s.w.iter().map(|v| v.as_slice()).collect();
        let rref: Vec<&[f32]> = s.r.iter().map(|v| v.as_slice()).collect();
        let bref: Vec<&[f32]> = s.b.iter().map(|v| v.as_slice()).collect();
        let weights = LstmWeights::pack(cfg, &wref, &rref, &bref);
        let wt = weights.transposed();
        let mut ws = LstmWorkspace::new(&cfg);
        prim.forward(&s.x, None, None, &weights, &mut ws);
        let dh_out = vec![1.0f32; cfg.t * cfg.n * cfg.k];
        let (grads, _) = prim.backward(&s.x, &dh_out, &wt, &ws);

        let loss = |x: &[f32], w: &[Vec<f32>], r: &[Vec<f32>], b: &[Vec<f32>]| -> f64 {
            let s2 = Setup {
                cfg,
                x: x.to_vec(),
                w: w.to_vec(),
                r: r.to_vec(),
                b: b.to_vec(),
            };
            let (h, _, _) = naive_sequence(&s2);
            (1..=cfg.t).map(|t| h[t].iter().map(|v| *v as f64).sum::<f64>()).sum()
        };
        let eps = 1e-3f32;
        // dx
        for idx in [0usize, 7, 13, 23] {
            let mut xp = s.x.clone();
            xp[idx] += eps;
            let mut xm = s.x.clone();
            xm[idx] -= eps;
            let num = (loss(&xp, &s.w, &s.r, &s.b) - loss(&xm, &s.w, &s.r, &s.b))
                / (2.0 * eps as f64);
            assert!(
                (num - grads.dx[idx] as f64).abs() < 5e-3,
                "dx[{}]: {} vs {}", idx, num, grads.dx[idx]
            );
        }
        // dW — every gate (unpack the blocked gradient first).
        for z in 0..GATES {
            let gw = cfg.k * cfg.c;
            let dwz = crate::tensor::layout::unpack_weights_2d(
                &grads.dw[z * gw..(z + 1) * gw],
                cfg.k, cfg.c, cfg.bk, cfg.bc,
            );
            for idx in [0usize, 5, 11] {
                let mut wp = s.w.clone();
                wp[z][idx] += eps;
                let mut wm = s.w.clone();
                wm[z][idx] -= eps;
                let num = (loss(&s.x, &wp, &s.r, &s.b) - loss(&s.x, &wm, &s.r, &s.b))
                    / (2.0 * eps as f64);
                assert!(
                    (num - dwz[idx] as f64).abs() < 5e-3,
                    "dW[{}][{}]: {} vs {}", z, idx, num, dwz[idx]
                );
            }
        }
        // dR — every gate.
        for z in 0..GATES {
            let gr = cfg.k * cfg.k;
            let drz = crate::tensor::layout::unpack_weights_2d(
                &grads.dr[z * gr..(z + 1) * gr],
                cfg.k, cfg.k, cfg.bk, cfg.bk,
            );
            for idx in [0usize, 6, 15] {
                let mut rp = s.r.clone();
                rp[z][idx] += eps;
                let mut rm = s.r.clone();
                rm[z][idx] -= eps;
                let num = (loss(&s.x, &s.w, &rp, &s.b) - loss(&s.x, &s.w, &rm, &s.b))
                    / (2.0 * eps as f64);
                assert!(
                    (num - drz[idx] as f64).abs() < 5e-3,
                    "dR[{}][{}]: {} vs {}", z, idx, num, drz[idx]
                );
            }
        }
        // db — every gate.
        for z in 0..GATES {
            for idx in [0usize, 3] {
                let mut bp = s.b.clone();
                bp[z][idx] += eps;
                let mut bm = s.b.clone();
                bm[z][idx] -= eps;
                let num = (loss(&s.x, &s.w, &s.r, &bp) - loss(&s.x, &s.w, &s.r, &bm))
                    / (2.0 * eps as f64);
                assert!(
                    (num - grads.db[z * cfg.k + idx] as f64).abs() < 5e-3,
                    "db[{}][{}]: {} vs {}", z, idx, num, grads.db[z * cfg.k + idx]
                );
            }
        }
    }

    /// Threading is a work-partitioning choice, never a math choice: the
    /// forward states and all four gradient tensors must be **bitwise**
    /// identical at any thread count (each `(nb, kb)`-style block is
    /// computed whole by exactly one task, with a fixed accumulation
    /// order, so partitioning only changes who computes a block).
    #[test]
    fn forward_and_backward_bit_identical_across_thread_counts() {
        let s = setup(8, 16, 16, 4, 99);
        let dh_out = Rng::new(5).vec_f32(s.cfg.t * s.cfg.n * s.cfg.k, -1.0, 1.0);
        let run = |threads: usize| {
            // Small blocks so the (nb × kb) task grid is genuinely
            // partitioned differently at each thread count.
            let cfg = s.cfg.with_blocking(4, 8, 8).with_threads(threads);
            let prim = LstmPrimitive::new(cfg);
            let wref: Vec<&[f32]> = s.w.iter().map(|v| v.as_slice()).collect();
            let rref: Vec<&[f32]> = s.r.iter().map(|v| v.as_slice()).collect();
            let bref: Vec<&[f32]> = s.b.iter().map(|v| v.as_slice()).collect();
            let weights = LstmWeights::pack(cfg, &wref, &rref, &bref);
            let wt = weights.transposed();
            let mut ws = LstmWorkspace::new(&cfg);
            prim.forward(&s.x, None, None, &weights, &mut ws);
            let (grads, _) = prim.backward(&s.x, &dh_out, &wt, &ws);
            (ws.h.clone(), ws.s.clone(), grads)
        };
        let (h1, s1, g1) = run(1);
        for threads in [2usize, 3, 4] {
            let (h, st, g) = run(threads);
            assert_eq!(h, h1, "h differs at {} threads", threads);
            assert_eq!(st, s1, "s differs at {} threads", threads);
            assert_eq!(g.dx, g1.dx, "dx differs at {} threads", threads);
            assert_eq!(g.dw, g1.dw, "dW differs at {} threads", threads);
            assert_eq!(g.dr, g1.dr, "dR differs at {} threads", threads);
            assert_eq!(g.db, g1.db, "db differs at {} threads", threads);
        }
    }

    #[test]
    fn shared_weights_pack_matches_training_pack_and_forward() {
        // One shared packed copy must produce bit-identical forwards to
        // the training-side LstmWeights pack, round-trip to the canonical
        // form exactly, and share its allocation across clones.
        let s = setup(4, 8, 8, 3, 77);
        let cfg = s.cfg;
        let prim = LstmPrimitive::new(cfg);
        let wref: Vec<&[f32]> = s.w.iter().map(|v| v.as_slice()).collect();
        let rref: Vec<&[f32]> = s.r.iter().map(|v| v.as_slice()).collect();
        let bref: Vec<&[f32]> = s.b.iter().map(|v| v.as_slice()).collect();
        let weights = LstmWeights::pack(cfg, &wref, &rref, &bref);
        // Canonical gate-major concatenations (the artifact layout).
        let w_cat: Vec<f32> = s.w.iter().flatten().copied().collect();
        let r_cat: Vec<f32> = s.r.iter().flatten().copied().collect();
        let b_cat: Vec<f32> = s.b.iter().flatten().copied().collect();
        let shared = LstmSharedWeights::pack(&cfg, &w_cat, &r_cat, &b_cat);
        assert_eq!(shared.w(), &weights.w[..], "same packed input weights");
        assert_eq!(shared.r(), &weights.r[..], "same packed recurrent weights");
        assert_eq!(shared.b(), &weights.b[..]);
        let (wp, rp, bp) = shared.to_plain();
        assert_eq!(wp, w_cat, "to_plain inverts pack bitwise");
        assert_eq!(rp, r_cat);
        assert_eq!(bp, b_cat);
        assert!(shared.matches(&cfg));
        assert_eq!(shared.clone().alloc_id(), shared.alloc_id(), "clones share the allocation");
        let mut ws_a = LstmWorkspace::new(&cfg);
        let mut ws_b = LstmWorkspace::new(&cfg);
        prim.forward(&s.x, None, None, &weights, &mut ws_a);
        prim.forward_shared(&s.x, None, None, &shared, &mut ws_b);
        assert_eq!(ws_a.h, ws_b.h, "shared-weight forward must be bit-identical");
        assert_eq!(ws_a.s, ws_b.s);
    }

    #[test]
    fn profiler_counts_brgemm_calls_exactly() {
        let _g = telemetry::test_lock();
        let p = telemetry::install();
        let s = setup(4, 8, 8, 3, 21);
        let cfg = s.cfg; // bn=4 cb=1 kb=1 nb=1
        let prim = LstmPrimitive::new(cfg);
        let wref: Vec<&[f32]> = s.w.iter().map(|v| v.as_slice()).collect();
        let rref: Vec<&[f32]> = s.r.iter().map(|v| v.as_slice()).collect();
        let bref: Vec<&[f32]> = s.b.iter().map(|v| v.as_slice()).collect();
        let weights = LstmWeights::pack(cfg, &wref, &rref, &bref);
        let wt = weights.transposed();
        let mut ws = LstmWorkspace::new(&cfg);
        prim.forward(&s.x, None, None, &weights, &mut ws);
        let dh_out = vec![1.0f32; cfg.t * cfg.n * cfg.k];
        let (_grads, _) = prim.backward(&s.x, &dh_out, &wt, &ws);
        let slot = p
            .slots()
            .into_iter()
            .find(|sl| sl.kind() == "lstm" && sl.label() == "n4 c8 k8 t3")
            .expect("slot registered at construction");
        let fwd = slot.pass_snapshot(Pass::Fwd);
        assert_eq!(fwd.calls, 1);
        assert_eq!(fwd.brgemm_calls, 24, "T * Nb * Kb * gates * 2 = 3*1*1*4*2");
        assert_eq!(fwd.flops, cfg.fwd_flops() as u64);
        let bwd = slot.pass_snapshot(Pass::Bwd);
        assert_eq!(bwd.brgemm_calls, 6, "T * Nb * (Kb + Cb) = 3*1*2");
        let upd = slot.pass_snapshot(Pass::Upd);
        assert_eq!(upd.brgemm_calls, 8, "gates * Kb * (Cb + Kb) = 4*1*2");
        telemetry::uninstall();
    }

    /// Prefix execution: running `t_run < cfg.t` steps over a
    /// full-capacity config must be **bit-identical** (forward states and
    /// every gradient tensor) to a config built at exactly `t = t_run`
    /// with the same blocking — that equivalence is what lets one tuned
    /// config and one workspace serve a whole length bucket.
    #[test]
    fn prefix_execution_matches_shorter_config() {
        let (n, c, k, t_cap, t_run) = (4usize, 8usize, 8usize, 5usize, 3usize);
        let s = setup(n, c, k, t_cap, 63);
        let wref: Vec<&[f32]> = s.w.iter().map(|v| v.as_slice()).collect();
        let rref: Vec<&[f32]> = s.r.iter().map(|v| v.as_slice()).collect();
        let bref: Vec<&[f32]> = s.b.iter().map(|v| v.as_slice()).collect();
        let dh_out = Rng::new(8).vec_f32(t_run * n * k, -1.0, 1.0);

        // Full-capacity config, prefix execution.
        let cfg_cap = s.cfg;
        let prim_cap = LstmPrimitive::new(cfg_cap);
        let weights_cap = LstmWeights::pack(cfg_cap, &wref, &rref, &bref);
        let wt_cap = weights_cap.transposed();
        let mut ws_cap = LstmWorkspace::new(&cfg_cap);
        prim_cap.forward_t(&s.x, None, None, &weights_cap, &mut ws_cap, t_run);
        let (g_cap, _) = prim_cap.backward_t(&s.x, &dh_out, &wt_cap, &ws_cap, t_run);

        // Exact-length config over the same x prefix.
        let cfg_ex = LstmConfig::new(n, c, k, t_run)
            .with_blocking(cfg_cap.bn, cfg_cap.bc, cfg_cap.bk);
        let prim_ex = LstmPrimitive::new(cfg_ex);
        let weights_ex = LstmWeights::pack(cfg_ex, &wref, &rref, &bref);
        let wt_ex = weights_ex.transposed();
        let mut ws_ex = LstmWorkspace::new(&cfg_ex);
        let x_prefix = &s.x[..t_run * n * c];
        prim_ex.forward(x_prefix, None, None, &weights_ex, &mut ws_ex);
        let (g_ex, _) = prim_ex.backward(x_prefix, &dh_out, &wt_ex, &ws_ex);

        let nk = n * k;
        assert_eq!(
            &ws_cap.h[..(t_run + 1) * nk],
            &ws_ex.h[..],
            "h prefix must be bit-identical"
        );
        assert_eq!(&ws_cap.s[..(t_run + 1) * nk], &ws_ex.s[..]);
        assert_eq!(g_cap.dx, g_ex.dx, "dx over the executed prefix");
        assert_eq!(g_cap.dw, g_ex.dw, "dW accumulates over exactly t_run steps");
        assert_eq!(g_cap.dr, g_ex.dr);
        assert_eq!(g_cap.db, g_ex.db);

        // And the shared-weights serving path agrees with the training path
        // under prefix execution too.
        let w_cat: Vec<f32> = s.w.iter().flatten().copied().collect();
        let r_cat: Vec<f32> = s.r.iter().flatten().copied().collect();
        let b_cat: Vec<f32> = s.b.iter().flatten().copied().collect();
        let shared = LstmSharedWeights::pack(&cfg_cap, &w_cat, &r_cat, &b_cat);
        let mut ws_sh = LstmWorkspace::new(&cfg_cap);
        prim_cap.forward_shared_t(&s.x, None, None, &shared, &mut ws_sh, t_run);
        assert_eq!(&ws_sh.h[..(t_run + 1) * nk], &ws_ex.h[..]);
    }

    #[test]
    fn initial_state_is_used() {
        let s = setup(3, 4, 4, 1, 9);
        let cfg = s.cfg;
        let prim = LstmPrimitive::new(cfg);
        let wref: Vec<&[f32]> = s.w.iter().map(|v| v.as_slice()).collect();
        let rref: Vec<&[f32]> = s.r.iter().map(|v| v.as_slice()).collect();
        let bref: Vec<&[f32]> = s.b.iter().map(|v| v.as_slice()).collect();
        let weights = LstmWeights::pack(cfg, &wref, &rref, &bref);
        let mut rng = Rng::new(77);
        let h0 = rng.vec_f32(cfg.n * cfg.k, -0.5, 0.5);
        let s0 = rng.vec_f32(cfg.n * cfg.k, -0.5, 0.5);
        let mut ws = LstmWorkspace::new(&cfg);
        prim.forward(&s.x, Some(&h0), Some(&s0), &weights, &mut ws);
        let w: [&[f32]; 4] = [&s.w[0], &s.w[1], &s.w[2], &s.w[3]];
        let r: [&[f32]; 4] = [&s.r[0], &s.r[1], &s.r[2], &s.r[3]];
        let b: [&[f32]; 4] = [&s.b[0], &s.b[1], &s.b[2], &s.b[3]];
        let (.., h_t) = naive::lstm_step(cfg.n, cfg.c, cfg.k, &s.x, &h0, &s0, &w, &r, &b);
        let got = ws.h_t(&cfg, 0);
        for i in 0..cfg.n * cfg.k {
            assert!((got[i] - h_t[i]).abs() < 1e-4);
        }
    }
}
