//! Naive reference implementations (correctness oracles).
//!
//! Straightforward loop nests over plain (non-blocked) layouts with f64
//! accumulation. Shared by the unit/property tests of every optimized
//! primitive and by the bench harnesses as the "textbook" lower bound.
//! Deliberately no code shared with the optimized paths.

use super::eltwise::Act;

/// FC forward: `Y[n][k] = act(Σ_c W[k][c]·X[n][c] + b[k])`.
pub fn fc_fwd(
    n: usize,
    c: usize,
    k: usize,
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    act: Act,
) -> Vec<f32> {
    let mut y = vec![0.0f32; n * k];
    for i in 0..n {
        for j in 0..k {
            let mut acc = bias[j] as f64;
            for cc in 0..c {
                acc += w[j * c + cc] as f64 * x[i * c + cc] as f64;
            }
            y[i * k + j] = act.apply(acc as f32);
        }
    }
    y
}

/// FC backward-by-data: `dX[n][c] = Σ_k dZ[n][k]·W[k][c]` where dZ is the
/// pre-activation gradient.
pub fn fc_bwd_data(n: usize, c: usize, k: usize, dz: &[f32], w: &[f32]) -> Vec<f32> {
    let mut dx = vec![0.0f32; n * c];
    for i in 0..n {
        for cc in 0..c {
            let mut acc = 0.0f64;
            for j in 0..k {
                acc += dz[i * k + j] as f64 * w[j * c + cc] as f64;
            }
            dx[i * c + cc] = acc as f32;
        }
    }
    dx
}

/// FC weight update: `dW[k][c] = Σ_n dZ[n][k]·X[n][c]`, `db[k] = Σ_n dZ[n][k]`.
pub fn fc_upd(
    n: usize,
    c: usize,
    k: usize,
    x: &[f32],
    dz: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut dw = vec![0.0f32; k * c];
    let mut db = vec![0.0f32; k];
    for j in 0..k {
        for cc in 0..c {
            let mut acc = 0.0f64;
            for i in 0..n {
                acc += dz[i * k + j] as f64 * x[i * c + cc] as f64;
            }
            dw[j * c + cc] = acc as f32;
        }
        let mut acc = 0.0f64;
        for i in 0..n {
            acc += dz[i * k + j] as f64;
        }
        db[j] = acc as f32;
    }
    (dw, db)
}

/// Direct convolution forward over plain NCHW / KCRS layouts.
/// `pad` is symmetric spatial zero-padding; `str` the stride.
#[allow(clippy::too_many_arguments)]
pub fn conv_fwd(
    n: usize,
    c: usize,
    k: usize,
    h: usize,
    w: usize,
    r: usize,
    s: usize,
    str_: usize,
    pad: usize,
    x: &[f32],
    wt: &[f32],
) -> Vec<f32> {
    let p = (h + 2 * pad - r) / str_ + 1;
    let q = (w + 2 * pad - s) / str_ + 1;
    let mut y = vec![0.0f32; n * k * p * q];
    for ni in 0..n {
        for kk in 0..k {
            for oj in 0..p {
                for oi in 0..q {
                    let mut acc = 0.0f64;
                    for cc in 0..c {
                        for rr in 0..r {
                            for ss in 0..s {
                                let ij = (oj * str_ + rr) as isize - pad as isize;
                                let ii = (oi * str_ + ss) as isize - pad as isize;
                                if ij < 0 || ii < 0 || ij >= h as isize || ii >= w as isize {
                                    continue;
                                }
                                let xv = x[((ni * c + cc) * h + ij as usize) * w + ii as usize];
                                let wv = wt[((kk * c + cc) * r + rr) * s + ss];
                                acc += xv as f64 * wv as f64;
                            }
                        }
                    }
                    y[((ni * k + kk) * p + oj) * q + oi] = acc as f32;
                }
            }
        }
    }
    y
}

/// Convolution backward-by-data: gradient w.r.t. the input.
#[allow(clippy::too_many_arguments)]
pub fn conv_bwd_data(
    n: usize,
    c: usize,
    k: usize,
    h: usize,
    w: usize,
    r: usize,
    s: usize,
    str_: usize,
    pad: usize,
    dy: &[f32],
    wt: &[f32],
) -> Vec<f32> {
    let p = (h + 2 * pad - r) / str_ + 1;
    let q = (w + 2 * pad - s) / str_ + 1;
    let mut dx = vec![0.0f32; n * c * h * w];
    for ni in 0..n {
        for kk in 0..k {
            for oj in 0..p {
                for oi in 0..q {
                    let g = dy[((ni * k + kk) * p + oj) * q + oi] as f64;
                    for cc in 0..c {
                        for rr in 0..r {
                            for ss in 0..s {
                                let ij = (oj * str_ + rr) as isize - pad as isize;
                                let ii = (oi * str_ + ss) as isize - pad as isize;
                                if ij < 0 || ii < 0 || ij >= h as isize || ii >= w as isize {
                                    continue;
                                }
                                let wv = wt[((kk * c + cc) * r + rr) * s + ss] as f64;
                                dx[((ni * c + cc) * h + ij as usize) * w + ii as usize] +=
                                    (g * wv) as f32;
                            }
                        }
                    }
                }
            }
        }
    }
    dx
}

/// Convolution weight update: gradient w.r.t. the weights.
#[allow(clippy::too_many_arguments)]
pub fn conv_upd(
    n: usize,
    c: usize,
    k: usize,
    h: usize,
    w: usize,
    r: usize,
    s: usize,
    str_: usize,
    pad: usize,
    x: &[f32],
    dy: &[f32],
) -> Vec<f32> {
    let p = (h + 2 * pad - r) / str_ + 1;
    let q = (w + 2 * pad - s) / str_ + 1;
    let mut dw = vec![0.0f32; k * c * r * s];
    for kk in 0..k {
        for cc in 0..c {
            for rr in 0..r {
                for ss in 0..s {
                    let mut acc = 0.0f64;
                    for ni in 0..n {
                        for oj in 0..p {
                            for oi in 0..q {
                                let ij = (oj * str_ + rr) as isize - pad as isize;
                                let ii = (oi * str_ + ss) as isize - pad as isize;
                                if ij < 0 || ii < 0 || ij >= h as isize || ii >= w as isize {
                                    continue;
                                }
                                let xv =
                                    x[((ni * c + cc) * h + ij as usize) * w + ii as usize] as f64;
                                let g = dy[((ni * k + kk) * p + oj) * q + oi] as f64;
                                acc += xv * g;
                            }
                        }
                    }
                    dw[((kk * c + cc) * r + rr) * s + ss] = acc as f32;
                }
            }
        }
    }
    dw
}

/// Convolution bias gradient: `db[k] = Σ_{n,p,q} dY[n][k][p][q]`.
pub fn conv_bias_upd(n: usize, k: usize, p: usize, q: usize, dy: &[f32]) -> Vec<f32> {
    assert_eq!(dy.len(), n * k * p * q);
    let mut db = vec![0.0f32; k];
    for ni in 0..n {
        for kk in 0..k {
            let mut acc = 0.0f64;
            for oj in 0..p {
                for oi in 0..q {
                    acc += dy[((ni * k + kk) * p + oj) * q + oi] as f64;
                }
            }
            db[kk] += acc as f32;
        }
    }
    db
}

/// One LSTM forward step over plain layouts (Equations 1-6 verbatim).
/// Weights `w_*` are `K×C`, recurrent `r_*` are `K×K`, biases length K.
/// Returns `(i, g, f, o, s_t, h_t)` each `N×K` (g = candidate `c_t`).
#[allow(clippy::too_many_arguments, clippy::type_complexity)]
pub fn lstm_step(
    n: usize,
    c: usize,
    k: usize,
    x_t: &[f32],
    h_prev: &[f32],
    s_prev: &[f32],
    w: &[&[f32]; 4],
    r: &[&[f32]; 4],
    b: &[&[f32]; 4],
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let gate = |wi: &[f32], ri: &[f32], bi: &[f32], act: Act| -> Vec<f32> {
        let mut z = vec![0.0f32; n * k];
        for ni in 0..n {
            for kk in 0..k {
                let mut acc = bi[kk] as f64;
                for cc in 0..c {
                    acc += wi[kk * c + cc] as f64 * x_t[ni * c + cc] as f64;
                }
                for kk2 in 0..k {
                    acc += ri[kk * k + kk2] as f64 * h_prev[ni * k + kk2] as f64;
                }
                z[ni * k + kk] = act.apply(acc as f32);
            }
        }
        z
    };
    let i = gate(w[0], r[0], b[0], Act::Sigmoid);
    let g = gate(w[1], r[1], b[1], Act::Tanh);
    let f = gate(w[2], r[2], b[2], Act::Sigmoid);
    let o = gate(w[3], r[3], b[3], Act::Sigmoid);
    let mut s_t = vec![0.0f32; n * k];
    let mut h_t = vec![0.0f32; n * k];
    for idx in 0..n * k {
        s_t[idx] = f[idx] * s_prev[idx] + i[idx] * g[idx];
        h_t[idx] = o[idx] * s_t[idx].tanh();
    }
    (i, g, f, o, s_t, h_t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weights = copy.
        let (n, c, k, h, w) = (1, 2, 2, 3, 3);
        let x: Vec<f32> = (0..n * c * h * w).map(|i| i as f32).collect();
        let mut wt = vec![0.0; k * c];
        wt[0] = 1.0; // k0<-c0
        wt[3] = 1.0; // k1<-c1
        let y = conv_fwd(n, c, k, h, w, 1, 1, 1, 0, &x, &wt);
        assert_eq!(y, x);
    }

    #[test]
    fn conv_shapes_with_stride_and_pad() {
        let y = conv_fwd(1, 1, 1, 5, 5, 3, 3, 2, 1, &vec![1.0; 25], &vec![1.0; 9]);
        // P = Q = (5 + 2 - 3)/2 + 1 = 3
        assert_eq!(y.len(), 9);
        // center output sees all 9 inputs
        assert_eq!(y[4], 9.0);
        // corner output: kernel window [-1..1]² clipped → 4 inputs
        assert_eq!(y[0], 4.0);
    }

    #[test]
    fn conv_grad_check_finite_difference() {
        // dW and dX against central differences of a scalar loss Σ y².
        let (n, c, k, h, w, r, s, str_, pad) = (1, 2, 2, 4, 4, 3, 3, 1, 1);
        let mut rng = crate::util::rng::Rng::new(10);
        let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
        let wt = rng.vec_f32(k * c * r * s, -0.5, 0.5);
        let y = conv_fwd(n, c, k, h, w, r, s, str_, pad, &x, &wt);
        let dy: Vec<f32> = y.iter().map(|v| 2.0 * v).collect(); // d(Σy²)/dy
        let dx = conv_bwd_data(n, c, k, h, w, r, s, str_, pad, &dy, &wt);
        let dw = conv_upd(n, c, k, h, w, r, s, str_, pad, &x, &dy);
        let loss = |x: &[f32], wt: &[f32]| -> f64 {
            conv_fwd(n, c, k, h, w, r, s, str_, pad, x, wt)
                .iter()
                .map(|v| (*v as f64).powi(2))
                .sum()
        };
        let eps = 1e-3;
        for idx in [0usize, 7, 15, 31] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let num = (loss(&xp, &wt) - loss(&xm, &wt)) / (2.0 * eps as f64);
            assert!((num - dx[idx] as f64).abs() < 1e-2, "dx[{}]: {} vs {}", idx, num, dx[idx]);
        }
        for idx in [0usize, 5, 17, 35] {
            let mut wp = wt.to_vec();
            wp[idx] += eps;
            let mut wm = wt.to_vec();
            wm[idx] -= eps;
            let num = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps as f64);
            assert!((num - dw[idx] as f64).abs() < 1e-2, "dw[{}]: {} vs {}", idx, num, dw[idx]);
        }
    }

    #[test]
    fn fc_fwd_bias_and_act() {
        let y = fc_fwd(1, 2, 1, &[1.0, 2.0], &[3.0, 4.0], &[-10.0], Act::Relu);
        // 1*3 + 2*4 - 10 = 1
        assert_eq!(y, vec![1.0]);
        let y = fc_fwd(1, 2, 1, &[1.0, 2.0], &[3.0, 4.0], &[-12.0], Act::Relu);
        assert_eq!(y, vec![0.0]);
    }

    #[test]
    fn fc_grad_check() {
        let (n, c, k) = (3, 4, 5);
        let mut rng = crate::util::rng::Rng::new(11);
        let x = rng.vec_f32(n * c, -1.0, 1.0);
        let w = rng.vec_f32(k * c, -0.5, 0.5);
        let b = vec![0.0; k];
        let loss = |x: &[f32], w: &[f32]| -> f64 {
            fc_fwd(n, c, k, x, w, &b, Act::Identity).iter().map(|v| (*v as f64).powi(2)).sum()
        };
        let y = fc_fwd(n, c, k, &x, &w, &b, Act::Identity);
        let dz: Vec<f32> = y.iter().map(|v| 2.0 * v).collect();
        let dx = fc_bwd_data(n, c, k, &dz, &w);
        let (dw, _db) = fc_upd(n, c, k, &x, &dz);
        let eps = 1e-3;
        for idx in [0, 5, 11] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let num = (loss(&xp, &w) - loss(&xm, &w)) / (2.0 * eps as f64);
            assert!((num - dx[idx] as f64).abs() < 1e-2);
        }
        for idx in [0, 7, 19] {
            let mut wp = w.clone();
            wp[idx] += eps;
            let mut wm = w.clone();
            wm[idx] -= eps;
            let num = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps as f64);
            assert!((num - dw[idx] as f64).abs() < 1e-2);
        }
    }

    #[test]
    fn lstm_step_zero_weights_gives_neutral_gates() {
        let (n, c, k) = (2, 3, 4);
        let x = vec![0.5; n * c];
        let h0 = vec![0.0; n * k];
        let s0 = vec![0.0; n * k];
        let zw = vec![0.0; k * c];
        let zr = vec![0.0; k * k];
        let zb = vec![0.0; k];
        let (i, g, f, o, s, h) = lstm_step(
            n, c, k, &x, &h0, &s0,
            &[&zw, &zw, &zw, &zw],
            &[&zr, &zr, &zr, &zr],
            &[&zb, &zb, &zb, &zb],
        );
        for v in &i {
            assert!((v - 0.5).abs() < 1e-6);
        }
        for v in &g {
            assert!(v.abs() < 1e-6);
        }
        for v in &f {
            assert!((v - 0.5).abs() < 1e-6);
        }
        for v in &o {
            assert!((v - 0.5).abs() < 1e-6);
        }
        // s = 0.5*0 + 0.5*0 = 0; h = 0.5*tanh(0) = 0
        assert!(s.iter().all(|v| v.abs() < 1e-6));
        assert!(h.iter().all(|v| v.abs() < 1e-6));
    }
}
