//! Fully-connected layers via the batch-reduce GEMM kernel (Algorithm 5)
//! plus the coarse-grained large-GEMM baseline of §3.3.1.
//!
//! Blocked layouts (see [`crate::tensor::layout`]):
//! ```text
//!   X[Nb][Cb][bn][bc]   W[Kb][Cb][bc][bk]   Y[Nb][Kb][bn][bk]
//! ```
//! Forward work item = one `bn×bk` block of Y: a single BRGEMM call with
//! batch = Cb reduces all input-feature blocks into the output block and
//! applies bias + activation while the block is hot (fixing issues (i)-(iii)
//! of the large-GEMM formulation, §3.3.2).

use crate::brgemm::{BrgemmDesc, BrgemmKernel, Epilogue, Gemm};
use crate::primitives::eltwise::{act_backward, Act};
use crate::primitives::partition::{Partition2d, Strategy};
use crate::telemetry::{self, Pass, PrimSlot};
use crate::util::num::largest_divisor_le;
use crate::util::pool::{parallel_for, parallel_region, SharedMut};
use std::sync::Arc;
use std::time::Instant;

/// Shape + blocking for one FC layer.
#[derive(Debug, Clone, Copy)]
pub struct FcConfig {
    /// Mini-batch, input features, output features.
    pub n: usize,
    pub c: usize,
    pub k: usize,
    /// Blocking factors; must divide their dimensions.
    pub bn: usize,
    pub bc: usize,
    pub bk: usize,
    /// Forward BRGEMM variant (autotuned axis): the Cb accumulation chain
    /// has constant strides in both operands, so it can run through either
    /// the address-list or the strided kernel interface.
    pub fwd_strided: bool,
    /// Weight-update A-operand variant (autotuned axis): `false` reads X
    /// blocks transposed in place via the kernel's `a_kstride`; `true`
    /// physically transposes them per call first (the abl01 trade-off).
    pub upd_transpose: bool,
    /// Forward loop order / thread partition override; `None` = heuristic.
    pub par_strategy: Option<Strategy>,
    pub act: Act,
    pub nthreads: usize,
}

impl FcConfig {
    /// Default blocking: the paper-style 64-wide feature blocks (the
    /// microkernel's sweet spot) clamped to the problem size.
    pub fn new(n: usize, c: usize, k: usize, act: Act) -> FcConfig {
        FcConfig {
            n,
            c,
            k,
            bn: largest_divisor_le(n, 24),
            bc: largest_divisor_le(c, 64),
            bk: largest_divisor_le(k, 64),
            fwd_strided: false,
            upd_transpose: false,
            par_strategy: None,
            act,
            nthreads: 1,
        }
    }

    /// Set the blocking factors. Each factor must be ≥ 1 and is rounded
    /// *down* to the largest divisor of its dimension (`bn`|N, `bc`|C,
    /// `bk`|K) — a non-divisor block size would silently mis-shape the
    /// packed layouts, so it is never accepted verbatim.
    pub fn with_blocking(mut self, bn: usize, bc: usize, bk: usize) -> FcConfig {
        assert!(bn >= 1 && bc >= 1 && bk >= 1, "block sizes must be >= 1");
        self.bn = largest_divisor_le(self.n, bn);
        self.bc = largest_divisor_le(self.c, bc);
        self.bk = largest_divisor_le(self.k, bk);
        self.validate();
        self
    }

    pub fn with_threads(mut self, t: usize) -> FcConfig {
        self.nthreads = t;
        self
    }

    /// Select the strided forward kernel variant (autotuned axis).
    pub fn with_fwd_strided(mut self, strided: bool) -> FcConfig {
        self.fwd_strided = strided;
        self
    }

    /// Select the physical-transpose weight-update variant (autotuned axis).
    pub fn with_upd_transpose(mut self, transpose: bool) -> FcConfig {
        self.upd_transpose = transpose;
        self
    }

    /// Pin the forward loop order / thread partition strategy.
    pub fn with_loop_order(mut self, s: Strategy) -> FcConfig {
        self.par_strategy = Some(s);
        self
    }

    /// Forward-pass work partition honouring [`Self::par_strategy`].
    fn partition(&self, rows: usize, cols: usize, big_weights: bool) -> Partition2d {
        match self.par_strategy {
            Some(s) => Partition2d::new(rows, cols, self.nthreads, s),
            None => Partition2d::auto(rows, cols, self.nthreads, big_weights),
        }
    }

    fn validate(&self) {
        assert_eq!(self.n % self.bn, 0, "bn must divide N");
        assert_eq!(self.c % self.bc, 0, "bc must divide C");
        assert_eq!(self.k % self.bk, 0, "bk must divide K");
    }

    pub fn nb(&self) -> usize {
        self.n / self.bn
    }
    pub fn cb(&self) -> usize {
        self.c / self.bc
    }
    pub fn kb(&self) -> usize {
        self.k / self.bk
    }

    /// Flops of one forward pass (GEMM part).
    pub fn flops(&self) -> f64 {
        2.0 * self.n as f64 * self.c as f64 * self.k as f64
    }
}

/// Packed FC weights + bias split out of execution state and shared via
/// [`Arc`]: one packed copy backs any number of [`FcPrimitive`] execution
/// plans (the serving subsystem builds one plan per batch bucket over a
/// single weight allocation). The packed layout depends only on the
/// feature blocking `(bk, bc)` — never on the mini-batch — so every plan
/// whose blocking matches can execute against the same buffer;
/// [`Self::matches`] is the compatibility check the executor asserts.
#[derive(Clone)]
pub struct FcSharedWeights {
    pub k: usize,
    pub c: usize,
    pub bk: usize,
    pub bc: usize,
    w: Arc<Vec<f32>>,    // packed [Kb][Cb][bc][bk]
    bias: Arc<Vec<f32>>, // [K]
}

impl FcSharedWeights {
    /// Pack plain `[K][C]` weights + `[K]` bias once for the blocking of
    /// `cfg`. Cloning the result never re-packs or re-allocates the
    /// buffers — it bumps the [`Arc`]s.
    pub fn pack(cfg: &FcConfig, w_plain: &[f32], bias: &[f32]) -> FcSharedWeights {
        assert_eq!(w_plain.len(), cfg.k * cfg.c);
        assert_eq!(bias.len(), cfg.k);
        let packed =
            crate::tensor::layout::pack_weights_2d(w_plain, cfg.k, cfg.c, cfg.bk, cfg.bc);
        FcSharedWeights {
            k: cfg.k,
            c: cfg.c,
            bk: cfg.bk,
            bc: cfg.bc,
            w: Arc::new(packed),
            bias: Arc::new(bias.to_vec()),
        }
    }

    /// Wrap already-packed buffers (e.g. lifted out of a trained model).
    pub fn from_packed(cfg: &FcConfig, w: Vec<f32>, bias: Vec<f32>) -> FcSharedWeights {
        assert_eq!(w.len(), cfg.k * cfg.c);
        assert_eq!(bias.len(), cfg.k);
        FcSharedWeights {
            k: cfg.k,
            c: cfg.c,
            bk: cfg.bk,
            bc: cfg.bc,
            w: Arc::new(w),
            bias: Arc::new(bias),
        }
    }

    pub fn w(&self) -> &[f32] {
        &self.w
    }

    pub fn bias(&self) -> &[f32] {
        &self.bias
    }

    /// Unpack to the canonical plain layouts (`[K][C]` row-major weights,
    /// `[K]` bias) — the weight-extraction path the model-artifact
    /// subsystem uses. Packing is a pure permutation, so
    /// `pack(cfg, to_plain())` reproduces the packed buffer bit for bit.
    pub fn to_plain(&self) -> (Vec<f32>, Vec<f32>) {
        (
            crate::tensor::layout::unpack_weights_2d(&self.w, self.k, self.c, self.bk, self.bc),
            self.bias.to_vec(),
        )
    }

    /// Can an execution plan with this config run against these weights?
    /// Shape and feature blocking must agree (`bn` is free — that is the
    /// whole point of sharing across batch buckets).
    pub fn matches(&self, cfg: &FcConfig) -> bool {
        self.k == cfg.k && self.c == cfg.c && self.bk == cfg.bk && self.bc == cfg.bc
    }

    /// Stable identity of the underlying packed-weight allocation; two
    /// clones share it. Used by tests to assert weights are allocated
    /// exactly once however many bucket plans exist.
    pub fn alloc_id(&self) -> usize {
        Arc::as_ptr(&self.w) as usize
    }
}

/// The BRGEMM-based FC primitive (forward + both training passes).
pub struct FcPrimitive {
    pub cfg: FcConfig,
    fwd_kernel: BrgemmKernel,
    bwd_kernel: BrgemmKernel,
    upd_kernel: BrgemmKernel,
    /// Profiler slot — `None` (one branch per pass) unless a
    /// [`crate::telemetry`] profiler was installed at construction time.
    tele: Option<Arc<PrimSlot>>,
}

impl FcPrimitive {
    pub fn new(cfg: FcConfig) -> FcPrimitive {
        cfg.validate();
        // FWD: C_blk[bn×bk] = Σ_cb X_blk[bn×bc]·W_blk[bc×bk], bias+act fused.
        let fwd = BrgemmKernel::new(BrgemmDesc {
            m: cfg.bn,
            n: cfg.bk,
            k: cfg.bc,
            lda: cfg.bc,
            ldb: cfg.bk,
            ldc: cfg.bk,
            a_kstride: 1,
            alpha: 1.0,
            beta: 0.0,
        })
        .with_epilogue(Epilogue::BiasAct(cfg.act));
        // BWD: dX_blk[bn×bc] = Σ_kb dZ_blk[bn×bk]·Wᵀ_blk[bk×bc].
        let bwd = BrgemmKernel::new(BrgemmDesc {
            m: cfg.bn,
            n: cfg.bc,
            k: cfg.bk,
            lda: cfg.bk,
            ldb: cfg.bc,
            ldc: cfg.bc,
            a_kstride: 1,
            alpha: 1.0,
            beta: 0.0,
        });
        // UPD: dW_blk[bc×bk] = Σ_nb Xᵀ_blk[bc×bn]·dZ_blk[bn×bk].
        // Default: X blocks are [bn][bc] and are read transposed in place
        // via a_kstride (lda = 1 walks channels, k-stride bc walks the
        // batch). With `upd_transpose` the blocks are physically
        // transposed to [bc][bn] first and read at unit stride — which
        // wins once the strided broadcast walk stops fitting in cache
        // (see the abl01 bench); the tuner picks per shape.
        let upd = if cfg.upd_transpose {
            BrgemmKernel::new(BrgemmDesc {
                m: cfg.bc,
                n: cfg.bk,
                k: cfg.bn,
                lda: cfg.bn,
                ldb: cfg.bk,
                ldc: cfg.bk,
                a_kstride: 1,
                alpha: 1.0,
                beta: 0.0,
            })
        } else {
            BrgemmKernel::new(BrgemmDesc {
                m: cfg.bc,
                n: cfg.bk,
                k: cfg.bn,
                lda: 1,
                ldb: cfg.bk,
                ldc: cfg.bk,
                a_kstride: cfg.bc,
                alpha: 1.0,
                beta: 0.0,
            })
        };
        let tele = telemetry::register("fc", format!("n{} c{} k{}", cfg.n, cfg.c, cfg.k));
        FcPrimitive { cfg, fwd_kernel: fwd, bwd_kernel: bwd, upd_kernel: upd, tele }
    }

    /// Tensor bytes one pass touches (activations + weights + outputs +
    /// bias, f32) — the roofline's memory term for this shape.
    fn bytes_moved(&self) -> u64 {
        let c = &self.cfg;
        4 * (c.n * c.c + c.k * c.c + c.n * c.k + c.k) as u64
    }

    /// Like [`FcPrimitive::new`], but first consults the persistent tuning
    /// cache (shape + ISA + thread count key) and, on a hit, applies the
    /// cached winning blocking / kernel variants. On a miss the config is
    /// used as-is — populate the cache with the `tune` CLI subcommand or
    /// [`crate::autotune::tuner::tune_fc_cached`].
    pub fn tuned(cfg: FcConfig) -> FcPrimitive {
        FcPrimitive::new(crate::autotune::tuned_fc_config(cfg))
    }

    /// Forward against [`FcSharedWeights`]: asserts the blocking matches,
    /// then runs [`Self::forward`] on the shared buffers. This is the
    /// serving hot path — many batch-bucket plans, one weight copy.
    pub fn forward_shared(&self, x: &[f32], w: &FcSharedWeights, y: &mut [f32]) {
        assert!(
            w.matches(&self.cfg),
            "shared weights ({}x{} bk{} bc{}) do not match plan ({}x{} bk{} bc{})",
            w.k, w.c, w.bk, w.bc, self.cfg.k, self.cfg.c, self.cfg.bk, self.cfg.bc
        );
        self.forward(x, w.w(), w.bias(), y);
    }

    /// Forward: `y = act(x·Wᵀ + b)` on blocked layouts.
    pub fn forward(&self, x: &[f32], w: &[f32], bias: &[f32], y: &mut [f32]) {
        let c = &self.cfg;
        assert_eq!(x.len(), c.n * c.c);
        assert_eq!(w.len(), c.k * c.c);
        assert_eq!(bias.len(), c.k);
        assert_eq!(y.len(), c.n * c.k);
        let t0 = self.tele.as_ref().map(|_| Instant::now());
        let (nb, cb, kb) = (c.nb(), c.cb(), c.kb());
        let xblk = c.bn * c.bc;
        let wblk = c.bc * c.bk;
        let yblk = c.bn * c.bk;
        let part = c.partition(nb, kb, false);
        let shared = &SharedMut::new(y);
        parallel_region(c.nthreads, |tid| {
            // Offset buffers are only needed by the address-list variant.
            let (mut a_offs, mut b_offs) = if c.fwd_strided {
                (Vec::new(), Vec::new())
            } else {
                (vec![0usize; cb], vec![0usize; cb])
            };
            for (inb, ikb) in part.tasks(tid) {
                let y_off = (inb * kb + ikb) * yblk;
                // SAFETY: blocks are disjoint per task; tasks are disjoint
                // per thread (partition invariant).
                let yb = unsafe { shared.slice(y_off, yblk) };
                let bias_blk = &bias[ikb * c.bk..(ikb + 1) * c.bk];
                if c.fwd_strided {
                    // The Cb chain walks both operands at a fixed stride —
                    // the `strided-batch-gemm` special case of §2.
                    self.fwd_kernel.execute_strided(
                        &x[inb * cb * xblk..],
                        xblk,
                        &w[ikb * cb * wblk..],
                        wblk,
                        cb,
                        yb,
                        Some(bias_blk),
                    );
                } else {
                    for icb in 0..cb {
                        a_offs[icb] = (inb * cb + icb) * xblk;
                        b_offs[icb] = (ikb * cb + icb) * wblk;
                    }
                    self.fwd_kernel.execute_offs(x, &a_offs, w, &b_offs, yb, Some(bias_blk));
                }
            }
        });
        if let (Some(slot), Some(t0)) = (self.tele.as_ref(), t0) {
            // One BRGEMM call per (Nb × Kb) output block.
            slot.record(
                Pass::Fwd,
                (nb * kb) as u64,
                c.flops(),
                self.bytes_moved(),
                t0.elapsed(),
            );
        }
    }

    /// Pre-activation gradient: `dz = dy ∘ act'(y)` (blocked, elementwise).
    pub fn dz_from_dy(&self, dy: &[f32], y: &[f32], dz: &mut [f32]) {
        act_backward(self.cfg.act, dy, y, dz);
    }

    /// Backward by data: `dx = dz·W` on blocked layouts. `wt` is the packed
    /// transpose from [`crate::tensor::layout::transpose_packed_2d`].
    pub fn backward_data(&self, dz: &[f32], wt: &[f32], dx: &mut [f32]) {
        let c = &self.cfg;
        assert_eq!(dz.len(), c.n * c.k);
        assert_eq!(wt.len(), c.k * c.c);
        assert_eq!(dx.len(), c.n * c.c);
        let t0 = self.tele.as_ref().map(|_| Instant::now());
        let (nb, cb, kb) = (c.nb(), c.cb(), c.kb());
        let zblk = c.bn * c.bk;
        let wblk = c.bc * c.bk;
        let xblk = c.bn * c.bc;
        let part = Partition2d::auto(nb, cb, c.nthreads, false);
        let shared = &SharedMut::new(dx);
        parallel_region(c.nthreads, |tid| {
            let mut a_offs = vec![0usize; kb];
            let mut b_offs = vec![0usize; kb];
            for (inb, icb) in part.tasks(tid) {
                for ikb in 0..kb {
                    a_offs[ikb] = (inb * kb + ikb) * zblk;
                    b_offs[ikb] = (icb * kb + ikb) * wblk;
                }
                let off = (inb * cb + icb) * xblk;
                let out = unsafe { shared.slice(off, xblk) };
                self.bwd_kernel.execute_offs(dz, &a_offs, wt, &b_offs, out, None);
            }
        });
        if let (Some(slot), Some(t0)) = (self.tele.as_ref(), t0) {
            // One BRGEMM call per (Nb × Cb) input-gradient block.
            slot.record(
                Pass::Bwd,
                (nb * cb) as u64,
                c.flops(),
                self.bytes_moved(),
                t0.elapsed(),
            );
        }
    }

    /// Weight update: `dW = Xᵀ·dZ` (blocked), `db = Σ_n dz`.
    /// Parallelism is over (Kb × Cb) — the paper's observation that UPD has
    /// the least parallel slack for small C/K shows up here directly.
    pub fn update(&self, x: &[f32], dz: &[f32], dw: &mut [f32], db: &mut [f32]) {
        let c = &self.cfg;
        assert_eq!(x.len(), c.n * c.c);
        assert_eq!(dz.len(), c.n * c.k);
        assert_eq!(dw.len(), c.k * c.c);
        assert_eq!(db.len(), c.k);
        let t0 = self.tele.as_ref().map(|_| Instant::now());
        let (nb, cb, kb) = (c.nb(), c.cb(), c.kb());
        let xblk = c.bn * c.bc;
        let zblk = c.bn * c.bk;
        let wblk = c.bc * c.bk;
        // Physical-transpose variant: rewrite every X block [bn][bc] →
        // [bc][bn] once, so the accumulation chain reads unit-stride rows.
        // The copy is charged to this call — exactly the trade the tuner
        // weighs against the in-place a_kstride read. Blocks are disjoint,
        // so the transpose itself parallelises over them.
        let xt_owned: Vec<f32>;
        let x_eff: &[f32] = if c.upd_transpose {
            let mut xt = vec![0.0f32; x.len()];
            {
                let shared = &SharedMut::new(&mut xt);
                parallel_for(c.nthreads, nb * cb, |_tid, blk| {
                    let src = &x[blk * xblk..(blk + 1) * xblk];
                    // SAFETY: block regions are disjoint per index.
                    let dst = unsafe { shared.slice(blk * xblk, xblk) };
                    for row in 0..c.bn {
                        for col in 0..c.bc {
                            dst[col * c.bn + row] = src[row * c.bc + col];
                        }
                    }
                });
            }
            xt_owned = xt;
            &xt_owned
        } else {
            x
        };
        let part = Partition2d::new(kb, cb, c.nthreads, Strategy::Flat);
        let shared = &SharedMut::new(dw);
        parallel_region(c.nthreads, |tid| {
            let mut a_offs = vec![0usize; nb];
            let mut b_offs = vec![0usize; nb];
            for (ikb, icb) in part.tasks(tid) {
                for inb in 0..nb {
                    a_offs[inb] = (inb * cb + icb) * xblk;
                    b_offs[inb] = (inb * kb + ikb) * zblk;
                }
                let off = (ikb * cb + icb) * wblk;
                let out = unsafe { shared.slice(off, wblk) };
                self.upd_kernel.execute_offs(x_eff, &a_offs, dz, &b_offs, out, None);
            }
        });
        // Bias gradient: reduce dz over the batch (cheap, single-threaded).
        db.fill(0.0);
        for inb in 0..nb {
            for ikb in 0..kb {
                let blk = (inb * kb + ikb) * zblk;
                for r in 0..c.bn {
                    for j in 0..c.bk {
                        db[ikb * c.bk + j] += dz[blk + r * c.bk + j];
                    }
                }
            }
        }
        if let (Some(slot), Some(t0)) = (self.tele.as_ref(), t0) {
            // One BRGEMM call per (Kb × Cb) weight-gradient block; the
            // bias reduction is plain loops.
            slot.record(
                Pass::Upd,
                (kb * cb) as u64,
                c.flops(),
                self.bytes_moved(),
                t0.elapsed(),
            );
        }
    }
}

/// Coarse-grained baseline (§3.3.1): one large GEMM `Y = X·Wᵀ`, then a
/// separate full-tensor bias + activation sweep. Plain row-major layouts
/// (X: N×C, W: K×C, Y: N×K). The Wᵀ packing is done per call, as a BLAS
/// user would incur it (or the library would internally).
pub fn fc_forward_large_gemm(
    n: usize,
    c: usize,
    k: usize,
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    act: Act,
    y: &mut [f32],
) {
    // Transpose W (K×C → C×K) — the "packing" cost of the GEMM approach.
    let mut wt = vec![0.0f32; c * k];
    for kk in 0..k {
        for cc in 0..c {
            wt[cc * k + kk] = w[kk * c + cc];
        }
    }
    Gemm::dense(n, k, c).execute(x, &wt, y);
    // Exposed bandwidth-bound epilogue: the whole Y tensor is re-read from
    // memory (issue (iii) of §3.3.1).
    for i in 0..n {
        for j in 0..k {
            y[i * k + j] = act.apply(y[i * k + j] + bias[j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::primitives::naive;
    use crate::tensor::layout::{pack_act_2d, pack_weights_2d, transpose_packed_2d, unpack_act_2d, unpack_weights_2d};
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn setup(n: usize, c: usize, k: usize, _act: Act, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            rng.vec_f32(n * c, -1.0, 1.0),
            rng.vec_f32(k * c, -0.5, 0.5),
            rng.vec_f32(k, -0.2, 0.2),
        )
    }

    #[test]
    fn forward_matches_naive() {
        for &(n, c, k, act) in &[
            (8, 16, 16, Act::Relu),
            (24, 64, 32, Act::Sigmoid),
            (6, 8, 40, Act::Identity),
        ] {
            let (x, w, b) = setup(n, c, k, act, 42);
            let cfg = FcConfig::new(n, c, k, act);
            let prim = FcPrimitive::new(cfg);
            let xp = pack_act_2d(&x, n, c, cfg.bn, cfg.bc);
            let wp = pack_weights_2d(&w, k, c, cfg.bk, cfg.bc);
            let mut yp = vec![0.0; n * k];
            prim.forward(&xp, &wp, &b, &mut yp);
            let y = unpack_act_2d(&yp, n, k, cfg.bn, cfg.bk);
            let want = naive::fc_fwd(n, c, k, &x, &w, &b, act);
            for i in 0..y.len() {
                assert!((y[i] - want[i]).abs() < 1e-4, "({},{},{}) y[{}]", n, c, k, i);
            }
        }
    }

    #[test]
    fn forward_multithreaded_matches() {
        let (n, c, k) = (24, 32, 48);
        let (x, w, b) = setup(n, c, k, Act::Relu, 7);
        let cfg = FcConfig::new(n, c, k, Act::Relu).with_threads(4);
        let prim = FcPrimitive::new(cfg);
        let xp = pack_act_2d(&x, n, c, cfg.bn, cfg.bc);
        let wp = pack_weights_2d(&w, k, c, cfg.bk, cfg.bc);
        let mut yp = vec![0.0; n * k];
        prim.forward(&xp, &wp, &b, &mut yp);
        let y = unpack_act_2d(&yp, n, k, cfg.bn, cfg.bk);
        let want = naive::fc_fwd(n, c, k, &x, &w, &b, Act::Relu);
        for i in 0..y.len() {
            assert!((y[i] - want[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn backward_matches_naive() {
        let (n, c, k) = (12, 24, 16);
        let (x, w, b) = setup(n, c, k, Act::Sigmoid, 3);
        let cfg = FcConfig::new(n, c, k, Act::Sigmoid);
        let prim = FcPrimitive::new(cfg);
        let xp = pack_act_2d(&x, n, c, cfg.bn, cfg.bc);
        let wp = pack_weights_2d(&w, k, c, cfg.bk, cfg.bc);
        let mut yp = vec![0.0; n * k];
        prim.forward(&xp, &wp, &b, &mut yp);
        // upstream gradient = ones (packed layout of ones = ones)
        let dyp = vec![1.0; n * k];
        let mut dzp = vec![0.0; n * k];
        prim.dz_from_dy(&dyp, &yp, &mut dzp);
        // bwd data
        let wt = transpose_packed_2d(&wp, k, c, cfg.bk, cfg.bc);
        let mut dxp = vec![0.0; n * c];
        prim.backward_data(&dzp, &wt, &mut dxp);
        let dx = unpack_act_2d(&dxp, n, c, cfg.bn, cfg.bc);
        // naive: dz = dy * act'(y)
        let y = naive::fc_fwd(n, c, k, &x, &w, &b, Act::Sigmoid);
        let dz: Vec<f32> = y.iter().map(|&v| Act::Sigmoid.dydx_from_y(v)).collect();
        let dx_want = naive::fc_bwd_data(n, c, k, &dz, &w);
        for i in 0..dx.len() {
            assert!((dx[i] - dx_want[i]).abs() < 1e-4, "dx[{}]: {} vs {}", i, dx[i], dx_want[i]);
        }
        // upd
        let mut dwp = vec![0.0; k * c];
        let mut db = vec![0.0; k];
        prim.update(&xp, &dzp, &mut dwp, &mut db);
        let dw = unpack_weights_2d(&dwp, k, c, cfg.bk, cfg.bc);
        let (dw_want, db_want) = naive::fc_upd(n, c, k, &x, &dz);
        for i in 0..dw.len() {
            assert!((dw[i] - dw_want[i]).abs() < 1e-3, "dw[{}]: {} vs {}", i, dw[i], dw_want[i]);
        }
        for i in 0..k {
            assert!((db[i] - db_want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn shared_weights_to_plain_roundtrip_bitwise() {
        let (k, c) = (12, 20);
        let mut rng = Rng::new(71);
        let w = rng.vec_f32(k * c, -1.0, 1.0);
        let b = rng.vec_f32(k, -0.2, 0.2);
        let cfg = FcConfig::new(4, c, k, Act::Relu).with_blocking(4, 5, 4);
        let shared = FcSharedWeights::pack(&cfg, &w, &b);
        let (wp, bp) = shared.to_plain();
        assert_eq!(wp, w, "unpack(pack(w)) must be bitwise identical");
        assert_eq!(bp, b);
        // Re-pack under a *different* legal blocking and extract again:
        // the canonical form is blocking-agnostic.
        let cfg2 = FcConfig::new(2, c, k, Act::Relu).with_blocking(1, 10, 6);
        let shared2 = FcSharedWeights::pack(&cfg2, &wp, &bp);
        assert_eq!(shared2.to_plain().0, w);
    }

    #[test]
    fn large_gemm_baseline_matches_naive() {
        let (n, c, k) = (16, 32, 24);
        let (x, w, b) = setup(n, c, k, Act::Relu, 5);
        let mut y = vec![0.0; n * k];
        fc_forward_large_gemm(n, c, k, &x, &w, &b, Act::Relu, &mut y);
        let want = naive::fc_fwd(n, c, k, &x, &w, &b, Act::Relu);
        for i in 0..y.len() {
            assert!((y[i] - want[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn with_blocking_rounds_to_divisors() {
        let cfg = FcConfig::new(24, 64, 96, Act::Relu);
        // 7 ∤ 24 → 6; 48 ∤ 64 → 32; 200 > 96 → 96.
        let cfg = cfg.with_blocking(7, 48, 200);
        assert_eq!((cfg.bn, cfg.bc, cfg.bk), (6, 32, 96));
        let cfg = cfg.with_blocking(12, 16, 24);
        assert_eq!((cfg.bn, cfg.bc, cfg.bk), (12, 16, 24));
    }

    #[test]
    #[should_panic(expected = ">= 1")]
    fn with_blocking_rejects_zero() {
        FcConfig::new(8, 8, 8, Act::Relu).with_blocking(0, 1, 1);
    }

    #[test]
    fn strided_forward_variant_matches_offs() {
        let (n, c, k) = (12, 32, 24);
        let (x, w, b) = setup(n, c, k, Act::Relu, 31);
        let base = FcConfig::new(n, c, k, Act::Relu);
        let xp = pack_act_2d(&x, n, c, base.bn, base.bc);
        let wp = pack_weights_2d(&w, k, c, base.bk, base.bc);
        let mut y_offs = vec![0.0; n * k];
        FcPrimitive::new(base).forward(&xp, &wp, &b, &mut y_offs);
        let mut y_str = vec![0.0; n * k];
        FcPrimitive::new(base.with_fwd_strided(true)).forward(&xp, &wp, &b, &mut y_str);
        assert_eq!(y_offs, y_str, "strided variant must be bit-identical");
    }

    #[test]
    fn upd_transpose_variant_matches_inplace() {
        let (n, c, k) = (12, 24, 16);
        let (x, w, b) = setup(n, c, k, Act::Sigmoid, 37);
        let base = FcConfig::new(n, c, k, Act::Sigmoid);
        let xp = pack_act_2d(&x, n, c, base.bn, base.bc);
        let wp = pack_weights_2d(&w, k, c, base.bk, base.bc);
        let mut yp = vec![0.0; n * k];
        let prim = FcPrimitive::new(base);
        prim.forward(&xp, &wp, &b, &mut yp);
        let dyp = vec![1.0; n * k];
        let mut dzp = vec![0.0; n * k];
        prim.dz_from_dy(&dyp, &yp, &mut dzp);
        let run_upd = |cfg: FcConfig| {
            let p = FcPrimitive::new(cfg);
            let mut dw = vec![0.0; k * c];
            let mut db = vec![0.0; k];
            p.update(&xp, &dzp, &mut dw, &mut db);
            (dw, db)
        };
        let (dw_a, db_a) = run_upd(base);
        let (dw_b, db_b) = run_upd(base.with_upd_transpose(true));
        for i in 0..dw_a.len() {
            assert!((dw_a[i] - dw_b[i]).abs() < 1e-5, "dw[{}]: {} vs {}", i, dw_a[i], dw_b[i]);
        }
        assert_eq!(db_a, db_b);
    }

    #[test]
    fn loop_order_override_matches_auto() {
        let (n, c, k) = (24, 32, 48);
        let (x, w, b) = setup(n, c, k, Act::Relu, 41);
        let base = FcConfig::new(n, c, k, Act::Relu).with_threads(3);
        let xp = pack_act_2d(&x, n, c, base.bn, base.bc);
        let wp = pack_weights_2d(&w, k, c, base.bk, base.bc);
        let mut want = vec![0.0; n * k];
        FcPrimitive::new(base).forward(&xp, &wp, &b, &mut want);
        for s in [Strategy::MinibatchFirst, Strategy::FeatureFirst, Strategy::Flat] {
            let mut got = vec![0.0; n * k];
            FcPrimitive::new(base.with_loop_order(s)).forward(&xp, &wp, &b, &mut got);
            assert_eq!(got, want, "order {:?}", s);
        }
    }

    #[test]
    fn profiler_counts_brgemm_calls_exactly() {
        use crate::telemetry::{self, Pass};
        let _g = telemetry::test_lock();
        let p = telemetry::install();
        // Distinctive shape so this test's slot is unambiguous even if
        // other tests construct primitives while the profiler is live.
        let (n, c, k) = (20, 22, 26);
        let cfg = FcConfig::new(n, c, k, Act::Relu).with_blocking(5, 11, 13);
        assert_eq!((cfg.nb(), cfg.cb(), cfg.kb()), (4, 2, 2));
        let prim = FcPrimitive::new(cfg);
        let (x, w, b) = setup(n, c, k, Act::Relu, 9);
        let xp = pack_act_2d(&x, n, c, cfg.bn, cfg.bc);
        let wp = pack_weights_2d(&w, k, c, cfg.bk, cfg.bc);
        let mut yp = vec![0.0; n * k];
        prim.forward(&xp, &wp, &b, &mut yp);
        let dzp = vec![1.0; n * k];
        let wt = transpose_packed_2d(&wp, k, c, cfg.bk, cfg.bc);
        let mut dxp = vec![0.0; n * c];
        prim.backward_data(&dzp, &wt, &mut dxp);
        let mut dwp = vec![0.0; k * c];
        let mut db = vec![0.0; k];
        prim.update(&xp, &dzp, &mut dwp, &mut db);
        let slot = p
            .slots()
            .into_iter()
            .find(|s| s.kind() == "fc" && s.label() == "n20 c22 k26")
            .expect("slot registered at construction");
        let fwd = slot.pass_snapshot(Pass::Fwd);
        assert_eq!(fwd.calls, 1);
        assert_eq!(fwd.brgemm_calls, 8, "fwd issues one BRGEMM per (Nb x Kb) block");
        assert_eq!(fwd.flops, cfg.flops() as u64);
        let bwd = slot.pass_snapshot(Pass::Bwd);
        assert_eq!(bwd.brgemm_calls, 8, "bwd issues one BRGEMM per (Nb x Cb) block");
        let upd = slot.pass_snapshot(Pass::Upd);
        assert_eq!(upd.brgemm_calls, 4, "upd issues one BRGEMM per (Kb x Cb) block");
        telemetry::uninstall();
    }

    #[test]
    fn property_fwd_random_shapes_and_blockings() {
        Prop::new("fc fwd matches naive under random blocking").cases(25).run(|g| {
            let bn = g.usize(1..=6);
            let bc = g.usize(1..=8);
            let bk = g.usize(1..=20);
            let n = bn * g.usize(1..=4);
            let c = bc * g.usize(1..=4);
            let k = bk * g.usize(1..=4);
            let act = *g.choose(&[Act::Identity, Act::Relu, Act::Sigmoid, Act::Tanh]);
            let x = g.vec_f32(n * c, -1.0, 1.0);
            let w = g.vec_f32(k * c, -0.5, 0.5);
            let b = g.vec_f32(k, -0.2, 0.2);
            let nthreads = g.usize(1..=3);
            let cfg = FcConfig::new(n, c, k, act).with_blocking(bn, bc, bk).with_threads(nthreads);
            let prim = FcPrimitive::new(cfg);
            let xp = pack_act_2d(&x, n, c, bn, bc);
            let wp = pack_weights_2d(&w, k, c, bk, bc);
            let mut yp = vec![0.0; n * k];
            prim.forward(&xp, &wp, &b, &mut yp);
            let y = unpack_act_2d(&yp, n, k, bn, bk);
            let want = naive::fc_fwd(n, c, k, &x, &w, &b, act);
            for i in 0..y.len() {
                if (y[i] - want[i]).abs() > 1e-3 {
                    return Err(format!(
                        "n{} c{} k{} bn{} bc{} bk{} t{}: y[{}]={} want {}",
                        n, c, k, bn, bc, bk, nthreads, i, y[i], want[i]
                    ));
                }
            }
            Ok(())
        });
    }
}
