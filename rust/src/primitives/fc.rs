//! Fully-connected layers via the batch-reduce GEMM kernel (Algorithm 5)
//! plus the coarse-grained large-GEMM baseline of §3.3.1.
//!
//! Blocked layouts (see [`crate::tensor::layout`]):
//! ```text
//!   X[Nb][Cb][bn][bc]   W[Kb][Cb][bc][bk]   Y[Nb][Kb][bn][bk]
//! ```
//! Forward work item = one `bn×bk` block of Y: a single BRGEMM call with
//! batch = Cb reduces all input-feature blocks into the output block and
//! applies bias + activation while the block is hot (fixing issues (i)-(iii)
//! of the large-GEMM formulation, §3.3.2).

use crate::brgemm::{BrgemmDesc, BrgemmKernel, Epilogue, Gemm};
use crate::primitives::eltwise::{act_backward, Act};
use crate::primitives::partition::{Partition2d, Strategy};
use crate::util::pool::{parallel_region, SharedMut};

/// Shape + blocking for one FC layer.
#[derive(Debug, Clone, Copy)]
pub struct FcConfig {
    /// Mini-batch, input features, output features.
    pub n: usize,
    pub c: usize,
    pub k: usize,
    /// Blocking factors; must divide their dimensions.
    pub bn: usize,
    pub bc: usize,
    pub bk: usize,
    pub act: Act,
    pub nthreads: usize,
}

impl FcConfig {
    /// Default blocking: the paper-style 64-wide feature blocks (the
    /// microkernel's sweet spot) clamped to the problem size.
    pub fn new(n: usize, c: usize, k: usize, act: Act) -> FcConfig {
        let pick = |d: usize, pref: usize| {
            let mut b = pref.min(d);
            while d % b != 0 {
                b -= 1;
            }
            b
        };
        FcConfig {
            n,
            c,
            k,
            bn: pick(n, 24),
            bc: pick(c, 64),
            bk: pick(k, 64),
            act,
            nthreads: 1,
        }
    }

    pub fn with_blocking(mut self, bn: usize, bc: usize, bk: usize) -> FcConfig {
        self.bn = bn;
        self.bc = bc;
        self.bk = bk;
        self.validate();
        self
    }

    pub fn with_threads(mut self, t: usize) -> FcConfig {
        self.nthreads = t;
        self
    }

    fn validate(&self) {
        assert_eq!(self.n % self.bn, 0, "bn must divide N");
        assert_eq!(self.c % self.bc, 0, "bc must divide C");
        assert_eq!(self.k % self.bk, 0, "bk must divide K");
    }

    pub fn nb(&self) -> usize {
        self.n / self.bn
    }
    pub fn cb(&self) -> usize {
        self.c / self.bc
    }
    pub fn kb(&self) -> usize {
        self.k / self.bk
    }

    /// Flops of one forward pass (GEMM part).
    pub fn flops(&self) -> f64 {
        2.0 * self.n as f64 * self.c as f64 * self.k as f64
    }
}

/// The BRGEMM-based FC primitive (forward + both training passes).
pub struct FcPrimitive {
    pub cfg: FcConfig,
    fwd_kernel: BrgemmKernel,
    bwd_kernel: BrgemmKernel,
    upd_kernel: BrgemmKernel,
}

impl FcPrimitive {
    pub fn new(cfg: FcConfig) -> FcPrimitive {
        cfg.validate();
        // FWD: C_blk[bn×bk] = Σ_cb X_blk[bn×bc]·W_blk[bc×bk], bias+act fused.
        let fwd = BrgemmKernel::new(BrgemmDesc {
            m: cfg.bn,
            n: cfg.bk,
            k: cfg.bc,
            lda: cfg.bc,
            ldb: cfg.bk,
            ldc: cfg.bk,
            a_kstride: 1,
            alpha: 1.0,
            beta: 0.0,
        })
        .with_epilogue(Epilogue::BiasAct(cfg.act));
        // BWD: dX_blk[bn×bc] = Σ_kb dZ_blk[bn×bk]·Wᵀ_blk[bk×bc].
        let bwd = BrgemmKernel::new(BrgemmDesc {
            m: cfg.bn,
            n: cfg.bc,
            k: cfg.bk,
            lda: cfg.bk,
            ldb: cfg.bc,
            ldc: cfg.bc,
            a_kstride: 1,
            alpha: 1.0,
            beta: 0.0,
        });
        // UPD: dW_blk[bc×bk] = Σ_nb Xᵀ_blk[bc×bn]·dZ_blk[bn×bk].
        // X blocks are [bn][bc]; reading them transposed is free via
        // a_kstride (lda = 1 walks channels, k-stride bc walks the batch).
        let upd = BrgemmKernel::new(BrgemmDesc {
            m: cfg.bc,
            n: cfg.bk,
            k: cfg.bn,
            lda: 1,
            ldb: cfg.bk,
            ldc: cfg.bk,
            a_kstride: cfg.bc,
            alpha: 1.0,
            beta: 0.0,
        });
        FcPrimitive { cfg, fwd_kernel: fwd, bwd_kernel: bwd, upd_kernel: upd }
    }

    /// Forward: `y = act(x·Wᵀ + b)` on blocked layouts.
    pub fn forward(&self, x: &[f32], w: &[f32], bias: &[f32], y: &mut [f32]) {
        let c = &self.cfg;
        assert_eq!(x.len(), c.n * c.c);
        assert_eq!(w.len(), c.k * c.c);
        assert_eq!(bias.len(), c.k);
        assert_eq!(y.len(), c.n * c.k);
        let (nb, cb, kb) = (c.nb(), c.cb(), c.kb());
        let xblk = c.bn * c.bc;
        let wblk = c.bc * c.bk;
        let yblk = c.bn * c.bk;
        let part = Partition2d::auto(nb, kb, c.nthreads, false);
        let shared = &SharedMut::new(y);
        parallel_region(c.nthreads, |tid| {
            let mut a_offs = vec![0usize; cb];
            let mut b_offs = vec![0usize; cb];
            for (inb, ikb) in part.tasks(tid) {
                for icb in 0..cb {
                    a_offs[icb] = (inb * cb + icb) * xblk;
                    b_offs[icb] = (ikb * cb + icb) * wblk;
                }
                let y_off = (inb * kb + ikb) * yblk;
                // SAFETY: blocks are disjoint per task; tasks are disjoint
                // per thread (partition invariant).
                let yb = unsafe { shared.slice(y_off, yblk) };
                self.fwd_kernel.execute_offs(
                    x,
                    &a_offs,
                    w,
                    &b_offs,
                    yb,
                    Some(&bias[ikb * c.bk..(ikb + 1) * c.bk]),
                );
            }
        });
    }

    /// Pre-activation gradient: `dz = dy ∘ act'(y)` (blocked, elementwise).
    pub fn dz_from_dy(&self, dy: &[f32], y: &[f32], dz: &mut [f32]) {
        act_backward(self.cfg.act, dy, y, dz);
    }

    /// Backward by data: `dx = dz·W` on blocked layouts. `wt` is the packed
    /// transpose from [`crate::tensor::layout::transpose_packed_2d`].
    pub fn backward_data(&self, dz: &[f32], wt: &[f32], dx: &mut [f32]) {
        let c = &self.cfg;
        assert_eq!(dz.len(), c.n * c.k);
        assert_eq!(wt.len(), c.k * c.c);
        assert_eq!(dx.len(), c.n * c.c);
        let (nb, cb, kb) = (c.nb(), c.cb(), c.kb());
        let zblk = c.bn * c.bk;
        let wblk = c.bc * c.bk;
        let xblk = c.bn * c.bc;
        let part = Partition2d::auto(nb, cb, c.nthreads, false);
        let shared = &SharedMut::new(dx);
        parallel_region(c.nthreads, |tid| {
            let mut a_offs = vec![0usize; kb];
            let mut b_offs = vec![0usize; kb];
            for (inb, icb) in part.tasks(tid) {
                for ikb in 0..kb {
                    a_offs[ikb] = (inb * kb + ikb) * zblk;
                    b_offs[ikb] = (icb * kb + ikb) * wblk;
                }
                let off = (inb * cb + icb) * xblk;
                let out = unsafe { shared.slice(off, xblk) };
                self.bwd_kernel.execute_offs(dz, &a_offs, wt, &b_offs, out, None);
            }
        });
    }

    /// Weight update: `dW = Xᵀ·dZ` (blocked), `db = Σ_n dz`.
    /// Parallelism is over (Kb × Cb) — the paper's observation that UPD has
    /// the least parallel slack for small C/K shows up here directly.
    pub fn update(&self, x: &[f32], dz: &[f32], dw: &mut [f32], db: &mut [f32]) {
        let c = &self.cfg;
        assert_eq!(x.len(), c.n * c.c);
        assert_eq!(dz.len(), c.n * c.k);
        assert_eq!(dw.len(), c.k * c.c);
        assert_eq!(db.len(), c.k);
        let (nb, cb, kb) = (c.nb(), c.cb(), c.kb());
        let xblk = c.bn * c.bc;
        let zblk = c.bn * c.bk;
        let wblk = c.bc * c.bk;
        let part = Partition2d::new(kb, cb, c.nthreads, Strategy::Flat);
        let shared = &SharedMut::new(dw);
        parallel_region(c.nthreads, |tid| {
            let mut a_offs = vec![0usize; nb];
            let mut b_offs = vec![0usize; nb];
            for (ikb, icb) in part.tasks(tid) {
                for inb in 0..nb {
                    a_offs[inb] = (inb * cb + icb) * xblk;
                    b_offs[inb] = (inb * kb + ikb) * zblk;
                }
                let off = (ikb * cb + icb) * wblk;
                let out = unsafe { shared.slice(off, wblk) };
                self.upd_kernel.execute_offs(x, &a_offs, dz, &b_offs, out, None);
            }
        });
        // Bias gradient: reduce dz over the batch (cheap, single-threaded).
        db.fill(0.0);
        for inb in 0..nb {
            for ikb in 0..kb {
                let blk = (inb * kb + ikb) * zblk;
                for r in 0..c.bn {
                    for j in 0..c.bk {
                        db[ikb * c.bk + j] += dz[blk + r * c.bk + j];
                    }
                }
            }
        }
    }
}

/// Coarse-grained baseline (§3.3.1): one large GEMM `Y = X·Wᵀ`, then a
/// separate full-tensor bias + activation sweep. Plain row-major layouts
/// (X: N×C, W: K×C, Y: N×K). The Wᵀ packing is done per call, as a BLAS
/// user would incur it (or the library would internally).
pub fn fc_forward_large_gemm(
    n: usize,
    c: usize,
    k: usize,
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    act: Act,
    y: &mut [f32],
) {
    // Transpose W (K×C → C×K) — the "packing" cost of the GEMM approach.
    let mut wt = vec![0.0f32; c * k];
    for kk in 0..k {
        for cc in 0..c {
            wt[cc * k + kk] = w[kk * c + cc];
        }
    }
    Gemm::dense(n, k, c).execute(x, &wt, y);
    // Exposed bandwidth-bound epilogue: the whole Y tensor is re-read from
    // memory (issue (iii) of §3.3.1).
    for i in 0..n {
        for j in 0..k {
            y[i * k + j] = act.apply(y[i * k + j] + bias[j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::primitives::naive;
    use crate::tensor::layout::{pack_act_2d, pack_weights_2d, transpose_packed_2d, unpack_act_2d, unpack_weights_2d};
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn setup(n: usize, c: usize, k: usize, _act: Act, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            rng.vec_f32(n * c, -1.0, 1.0),
            rng.vec_f32(k * c, -0.5, 0.5),
            rng.vec_f32(k, -0.2, 0.2),
        )
    }

    #[test]
    fn forward_matches_naive() {
        for &(n, c, k, act) in &[
            (8, 16, 16, Act::Relu),
            (24, 64, 32, Act::Sigmoid),
            (6, 8, 40, Act::Identity),
        ] {
            let (x, w, b) = setup(n, c, k, act, 42);
            let cfg = FcConfig::new(n, c, k, act);
            let prim = FcPrimitive::new(cfg);
            let xp = pack_act_2d(&x, n, c, cfg.bn, cfg.bc);
            let wp = pack_weights_2d(&w, k, c, cfg.bk, cfg.bc);
            let mut yp = vec![0.0; n * k];
            prim.forward(&xp, &wp, &b, &mut yp);
            let y = unpack_act_2d(&yp, n, k, cfg.bn, cfg.bk);
            let want = naive::fc_fwd(n, c, k, &x, &w, &b, act);
            for i in 0..y.len() {
                assert!((y[i] - want[i]).abs() < 1e-4, "({},{},{}) y[{}]", n, c, k, i);
            }
        }
    }

    #[test]
    fn forward_multithreaded_matches() {
        let (n, c, k) = (24, 32, 48);
        let (x, w, b) = setup(n, c, k, Act::Relu, 7);
        let cfg = FcConfig::new(n, c, k, Act::Relu).with_threads(4);
        let prim = FcPrimitive::new(cfg);
        let xp = pack_act_2d(&x, n, c, cfg.bn, cfg.bc);
        let wp = pack_weights_2d(&w, k, c, cfg.bk, cfg.bc);
        let mut yp = vec![0.0; n * k];
        prim.forward(&xp, &wp, &b, &mut yp);
        let y = unpack_act_2d(&yp, n, k, cfg.bn, cfg.bk);
        let want = naive::fc_fwd(n, c, k, &x, &w, &b, Act::Relu);
        for i in 0..y.len() {
            assert!((y[i] - want[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn backward_matches_naive() {
        let (n, c, k) = (12, 24, 16);
        let (x, w, b) = setup(n, c, k, Act::Sigmoid, 3);
        let cfg = FcConfig::new(n, c, k, Act::Sigmoid);
        let prim = FcPrimitive::new(cfg);
        let xp = pack_act_2d(&x, n, c, cfg.bn, cfg.bc);
        let wp = pack_weights_2d(&w, k, c, cfg.bk, cfg.bc);
        let mut yp = vec![0.0; n * k];
        prim.forward(&xp, &wp, &b, &mut yp);
        // upstream gradient = ones (packed layout of ones = ones)
        let dyp = vec![1.0; n * k];
        let mut dzp = vec![0.0; n * k];
        prim.dz_from_dy(&dyp, &yp, &mut dzp);
        // bwd data
        let wt = transpose_packed_2d(&wp, k, c, cfg.bk, cfg.bc);
        let mut dxp = vec![0.0; n * c];
        prim.backward_data(&dzp, &wt, &mut dxp);
        let dx = unpack_act_2d(&dxp, n, c, cfg.bn, cfg.bc);
        // naive: dz = dy * act'(y)
        let y = naive::fc_fwd(n, c, k, &x, &w, &b, Act::Sigmoid);
        let dz: Vec<f32> = y.iter().map(|&v| Act::Sigmoid.dydx_from_y(v)).collect();
        let dx_want = naive::fc_bwd_data(n, c, k, &dz, &w);
        for i in 0..dx.len() {
            assert!((dx[i] - dx_want[i]).abs() < 1e-4, "dx[{}]: {} vs {}", i, dx[i], dx_want[i]);
        }
        // upd
        let mut dwp = vec![0.0; k * c];
        let mut db = vec![0.0; k];
        prim.update(&xp, &dzp, &mut dwp, &mut db);
        let dw = unpack_weights_2d(&dwp, k, c, cfg.bk, cfg.bc);
        let (dw_want, db_want) = naive::fc_upd(n, c, k, &x, &dz);
        for i in 0..dw.len() {
            assert!((dw[i] - dw_want[i]).abs() < 1e-3, "dw[{}]: {} vs {}", i, dw[i], dw_want[i]);
        }
        for i in 0..k {
            assert!((db[i] - db_want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn large_gemm_baseline_matches_naive() {
        let (n, c, k) = (16, 32, 24);
        let (x, w, b) = setup(n, c, k, Act::Relu, 5);
        let mut y = vec![0.0; n * k];
        fc_forward_large_gemm(n, c, k, &x, &w, &b, Act::Relu, &mut y);
        let want = naive::fc_fwd(n, c, k, &x, &w, &b, Act::Relu);
        for i in 0..y.len() {
            assert!((y[i] - want[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn property_fwd_random_shapes_and_blockings() {
        Prop::new("fc fwd matches naive under random blocking").cases(25).run(|g| {
            let bn = g.usize(1..=6);
            let bc = g.usize(1..=8);
            let bk = g.usize(1..=20);
            let n = bn * g.usize(1..=4);
            let c = bc * g.usize(1..=4);
            let k = bk * g.usize(1..=4);
            let act = *g.choose(&[Act::Identity, Act::Relu, Act::Sigmoid, Act::Tanh]);
            let x = g.vec_f32(n * c, -1.0, 1.0);
            let w = g.vec_f32(k * c, -0.5, 0.5);
            let b = g.vec_f32(k, -0.2, 0.2);
            let nthreads = g.usize(1..=3);
            let cfg = FcConfig::new(n, c, k, act).with_blocking(bn, bc, bk).with_threads(nthreads);
            let prim = FcPrimitive::new(cfg);
            let xp = pack_act_2d(&x, n, c, bn, bc);
            let wp = pack_weights_2d(&w, k, c, bk, bc);
            let mut yp = vec![0.0; n * k];
            prim.forward(&xp, &wp, &b, &mut yp);
            let y = unpack_act_2d(&yp, n, k, bn, bk);
            let want = naive::fc_fwd(n, c, k, &x, &w, &b, act);
            for i in 0..y.len() {
                if (y[i] - want[i]).abs() > 1e-3 {
                    return Err(format!(
                        "n{} c{} k{} bn{} bc{} bk{} t{}: y[{}]={} want {}",
                        n, c, k, bn, bc, bk, nthreads, i, y[i], want[i]
                    ));
                }
            }
            Ok(())
        });
    }
}
