//! Element-wise operators and their gradients.
//!
//! These are the non-GEMM stages of the DL primitives (σ, tanh, ReLU, the
//! Hadamard updates of the LSTM state). In the paper's design they are
//! *fused* onto output blocks immediately after a batch-reduce GEMM call,
//! while the block is cache-hot — they are deliberately simple slice
//! kernels here, because their performance comes from *where* they are
//! called, not from how they are coded (Table 1: 5.3% of LSTM runtime).

/// Activation functions usable as BRGEMM epilogues and standalone layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Identity,
    Relu,
    Sigmoid,
    Tanh,
}

impl Act {
    #[inline]
    pub fn apply(self, x: f32) -> f32 {
        match self {
            Act::Identity => x,
            Act::Relu => x.max(0.0),
            Act::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            Act::Tanh => x.tanh(),
        }
    }

    /// Derivative expressed in terms of the *output* y = act(x) — the form
    /// backprop wants, since the forward pass stores activations:
    /// σ' = y(1−y), tanh' = 1−y², relu' = [y > 0].
    #[inline]
    pub fn dydx_from_y(self, y: f32) -> f32 {
        match self {
            Act::Identity => 1.0,
            Act::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Act::Sigmoid => y * (1.0 - y),
            Act::Tanh => 1.0 - y * y,
        }
    }

    pub fn apply_slice(self, xs: &mut [f32]) {
        match self {
            Act::Identity => {}
            // ReLU vectorises trivially; give LLVM the pattern it folds to
            // a masked max.
            Act::Relu => {
                for x in xs {
                    *x = x.max(0.0);
                }
            }
            _ => {
                for x in xs {
                    *x = self.apply(*x);
                }
            }
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Act::Identity => "identity",
            Act::Relu => "relu",
            Act::Sigmoid => "sigmoid",
            Act::Tanh => "tanh",
        }
    }
}

/// `out[i] = a[i] * b[i]` (LSTM Hadamard products, Eq. 5-6).
pub fn hadamard(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == b.len() && b.len() == out.len());
    for i in 0..out.len() {
        out[i] = a[i] * b[i];
    }
}

/// `out[i] += a[i] * b[i]`.
pub fn hadamard_acc(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == b.len() && b.len() == out.len());
    for i in 0..out.len() {
        out[i] += a[i] * b[i];
    }
}

/// dX for an activation given upstream dY and stored outputs Y.
pub fn act_backward(act: Act, dy: &[f32], y: &[f32], dx: &mut [f32]) {
    debug_assert!(dy.len() == y.len() && y.len() == dx.len());
    for i in 0..dx.len() {
        dx[i] = dy[i] * act.dydx_from_y(y[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activations_pointwise() {
        assert_eq!(Act::Relu.apply(-2.0), 0.0);
        assert_eq!(Act::Relu.apply(3.0), 3.0);
        assert!((Act::Sigmoid.apply(0.0) - 0.5).abs() < 1e-7);
        assert!((Act::Tanh.apply(0.0)).abs() < 1e-7);
        assert_eq!(Act::Identity.apply(1.5), 1.5);
    }

    #[test]
    fn derivative_matches_finite_difference() {
        let eps = 1e-3f64;
        for act in [Act::Sigmoid, Act::Tanh, Act::Identity] {
            for &x in &[-2.0f32, -0.3, 0.0, 0.7, 2.5] {
                let y = act.apply(x);
                let num = (act.apply(x + eps as f32) as f64 - act.apply(x - eps as f32) as f64)
                    / (2.0 * eps);
                let ana = act.dydx_from_y(y) as f64;
                assert!((num - ana).abs() < 1e-3, "{:?} at {}: {} vs {}", act, x, num, ana);
            }
        }
    }

    #[test]
    fn relu_derivative_from_y() {
        assert_eq!(Act::Relu.dydx_from_y(0.0), 0.0);
        assert_eq!(Act::Relu.dydx_from_y(2.0), 1.0);
    }

    #[test]
    fn hadamard_ops() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        let mut o = [0.0; 3];
        hadamard(&a, &b, &mut o);
        assert_eq!(o, [4.0, 10.0, 18.0]);
        hadamard_acc(&a, &b, &mut o);
        assert_eq!(o, [8.0, 20.0, 36.0]);
    }

    #[test]
    fn act_backward_sigmoid() {
        let y = [0.5f32, 0.9];
        let dy = [1.0f32, 2.0];
        let mut dx = [0.0f32; 2];
        act_backward(Act::Sigmoid, &dy, &y, &mut dx);
        assert!((dx[0] - 0.25).abs() < 1e-6);
        assert!((dx[1] - 2.0 * 0.09).abs() < 1e-6);
    }
}
