//! Dense tensors and the paper's blocked layouts.
//!
//! The paper's primitives all run on *blocked* tensor formats chosen so
//! that every BRGEMM operand block is (nearly) contiguous and free of
//! large-power-of-two strided accesses (§3.1.2, §3.2.1, §3.3.2):
//!
//! ```text
//!   FC/LSTM weights  W[K][C]          → W[Kb][Cb][bc][bk]
//!   conv weights     W[K][C][R][S]    → W[Kb][Cb][R][S][bc][bk]
//!   conv activations I[N][C][H][W]    → I[N][Cb][H][W][bc]
//!   FC activations   X[N][C]          → X[Nb][Cb][bn][bc]
//! ```
//!
//! [`layout`] implements these reformats (and their inverses + the
//! transposed variants needed by the backward passes). The reformat cost
//! is part of the paper's accounting (Table 1 "tensor reformatting").

pub mod layout;

use crate::util::rng::Rng;

/// A dense row-major f32 tensor: shape + contiguous storage.
///
/// Deliberately minimal — the primitives operate on raw slices with
/// explicit layout structs; `Tensor` exists for ergonomic allocation,
/// initialisation and comparison in models, examples and tests.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// Uniform random in `[lo, hi)` from the given RNG (deterministic).
    pub fn rand(shape: &[usize], rng: &mut Rng, lo: f32, hi: f32) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_f32(&mut t.data, lo, hi);
        t
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row-major strides for the current shape.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let strides = self.strides();
        idx.iter().zip(&strides).map(|(i, s)| i * s).sum()
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let o = self.offset(idx);
        &mut self.data[o]
    }

    /// Max absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 error ‖a−b‖ / ‖b‖ (for comparisons against an oracle).
    pub fn rel_l2(&self, oracle: &Tensor) -> f64 {
        assert_eq!(self.shape, oracle.shape);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&oracle.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        (num / den.max(1e-30)).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_and_offsets() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
        assert_eq!(t.offset(&[1, 2, 3]), 12 + 8 + 3);
    }

    #[test]
    fn indexing_round_trip() {
        let mut t = Tensor::zeros(&[3, 5]);
        *t.at_mut(&[2, 4]) = 7.0;
        assert_eq!(t.at(&[2, 4]), 7.0);
        assert_eq!(t.data[14], 7.0);
    }

    #[test]
    fn rand_deterministic() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let a = Tensor::rand(&[4, 4], &mut r1, -1.0, 1.0);
        let b = Tensor::rand(&[4, 4], &mut r2, -1.0, 1.0);
        assert_eq!(a, b);
    }

    #[test]
    fn error_metrics() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
        assert!(a.rel_l2(&a) < 1e-12);
    }
}
