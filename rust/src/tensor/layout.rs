//! Blocked-layout reformats (paper §3: "freedom of data layout choice is a
//! fundamental cornerstone to enable high performance").
//!
//! Conventions: blocking factors `bk | K`, `bc | C`, `bn | N` must divide
//! their dimension (the primitives' planners choose factors accordingly —
//! the paper does the same; ResNet/GNMT sizes are highly composite).
//! All functions are plain index-shuffling copies; their runtime is what
//! Table 1 reports as "tensor reformatting".

/// W[K][C] (row-major, `w[k*c_dim + c]`) → W[Kb][Cb][bc][bk].
///
/// The inner `[bc][bk]` block is exactly the row-major `bc×bk` "B" operand
/// of a BRGEMM call with `ldb = bk` (note the transpose: output channel is
/// the *fast* axis so the microkernel vectorises along it).
pub fn pack_weights_2d(w: &[f32], k_dim: usize, c_dim: usize, bk: usize, bc: usize) -> Vec<f32> {
    assert_eq!(k_dim % bk, 0, "bk must divide K");
    assert_eq!(c_dim % bc, 0, "bc must divide C");
    assert_eq!(w.len(), k_dim * c_dim);
    let (kb, cb) = (k_dim / bk, c_dim / bc);
    let mut out = vec![0.0; w.len()];
    for ikb in 0..kb {
        for icb in 0..cb {
            let blk = ((ikb * cb) + icb) * bc * bk;
            for ic in 0..bc {
                for ik in 0..bk {
                    out[blk + ic * bk + ik] = w[(ikb * bk + ik) * c_dim + (icb * bc + ic)];
                }
            }
        }
    }
    out
}

/// Inverse of [`pack_weights_2d`].
pub fn unpack_weights_2d(wb: &[f32], k_dim: usize, c_dim: usize, bk: usize, bc: usize) -> Vec<f32> {
    let (kb, cb) = (k_dim / bk, c_dim / bc);
    assert_eq!(wb.len(), k_dim * c_dim);
    let mut out = vec![0.0; wb.len()];
    for ikb in 0..kb {
        for icb in 0..cb {
            let blk = ((ikb * cb) + icb) * bc * bk;
            for ic in 0..bc {
                for ik in 0..bk {
                    out[(ikb * bk + ik) * c_dim + (icb * bc + ic)] = wb[blk + ic * bk + ik];
                }
            }
        }
    }
    out
}

/// Packed W[Kb][Cb][bc][bk] → packed transpose Wᵀ[Cb][Kb][bk][bc]
/// (the backward-by-data operand: `dX = dY · Wᵀ`). Works directly on the
/// blocked form — this is the transpose the paper amortises across LSTM
/// time-steps.
pub fn transpose_packed_2d(
    wb: &[f32],
    k_dim: usize,
    c_dim: usize,
    bk: usize,
    bc: usize,
) -> Vec<f32> {
    let (kb, cb) = (k_dim / bk, c_dim / bc);
    let mut out = vec![0.0; wb.len()];
    for ikb in 0..kb {
        for icb in 0..cb {
            let src = ((ikb * cb) + icb) * bc * bk;
            let dst = ((icb * kb) + ikb) * bc * bk;
            for ic in 0..bc {
                for ik in 0..bk {
                    out[dst + ik * bc + ic] = wb[src + ic * bk + ik];
                }
            }
        }
    }
    out
}

/// X[N][C] → X[Nb][Cb][bn][bc] (FC activation blocking, Algorithm 5).
pub fn pack_act_2d(x: &[f32], n_dim: usize, c_dim: usize, bn: usize, bc: usize) -> Vec<f32> {
    let mut out = vec![0.0; x.len()];
    pack_act_2d_into(x, n_dim, c_dim, bn, bc, &mut out);
    out
}

/// [`pack_act_2d`] into a caller-owned buffer (`out.len() == x.len()`) —
/// the allocation-free variant the serving scratch path uses.
pub fn pack_act_2d_into(
    x: &[f32],
    n_dim: usize,
    c_dim: usize,
    bn: usize,
    bc: usize,
    out: &mut [f32],
) {
    assert_eq!(n_dim % bn, 0, "bn must divide N");
    assert_eq!(c_dim % bc, 0, "bc must divide C");
    assert_eq!(x.len(), n_dim * c_dim);
    assert_eq!(out.len(), x.len());
    let (nb, cb) = (n_dim / bn, c_dim / bc);
    for inb in 0..nb {
        for icb in 0..cb {
            let blk = ((inb * cb) + icb) * bn * bc;
            for r in 0..bn {
                for ic in 0..bc {
                    out[blk + r * bc + ic] = x[(inb * bn + r) * c_dim + (icb * bc + ic)];
                }
            }
        }
    }
}

/// Inverse of [`pack_act_2d`].
pub fn unpack_act_2d(xb: &[f32], n_dim: usize, c_dim: usize, bn: usize, bc: usize) -> Vec<f32> {
    let mut out = vec![0.0; xb.len()];
    unpack_act_2d_into(xb, n_dim, c_dim, bn, bc, &mut out);
    out
}

/// [`unpack_act_2d`] into a caller-owned buffer (allocation-free variant).
pub fn unpack_act_2d_into(
    xb: &[f32],
    n_dim: usize,
    c_dim: usize,
    bn: usize,
    bc: usize,
    out: &mut [f32],
) {
    let (nb, cb) = (n_dim / bn, c_dim / bc);
    assert_eq!(xb.len(), n_dim * c_dim);
    assert_eq!(out.len(), xb.len());
    for inb in 0..nb {
        for icb in 0..cb {
            let blk = ((inb * cb) + icb) * bn * bc;
            for r in 0..bn {
                for ic in 0..bc {
                    out[(inb * bn + r) * c_dim + (icb * bc + ic)] = xb[blk + r * bc + ic];
                }
            }
        }
    }
}

/// Conv weights W[K][C][R][S] → W[Kb][Cb][R][S][bc][bk] (paper §3.2.1).
#[allow(clippy::too_many_arguments)]
pub fn pack_conv_weights(
    w: &[f32],
    k_dim: usize,
    c_dim: usize,
    r_dim: usize,
    s_dim: usize,
    bk: usize,
    bc: usize,
) -> Vec<f32> {
    assert_eq!(k_dim % bk, 0);
    assert_eq!(c_dim % bc, 0);
    assert_eq!(w.len(), k_dim * c_dim * r_dim * s_dim);
    let (kb, cb) = (k_dim / bk, c_dim / bc);
    let mut out = vec![0.0; w.len()];
    for ikb in 0..kb {
        for icb in 0..cb {
            for r in 0..r_dim {
                for s in 0..s_dim {
                    let blk = ((((ikb * cb) + icb) * r_dim + r) * s_dim + s) * bc * bk;
                    for ic in 0..bc {
                        for ik in 0..bk {
                            let src = (((ikb * bk + ik) * c_dim + (icb * bc + ic)) * r_dim + r)
                                * s_dim
                                + s;
                            out[blk + ic * bk + ik] = w[src];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Inverse of [`pack_conv_weights`].
#[allow(clippy::too_many_arguments)]
pub fn unpack_conv_weights(
    wb: &[f32],
    k_dim: usize,
    c_dim: usize,
    r_dim: usize,
    s_dim: usize,
    bk: usize,
    bc: usize,
) -> Vec<f32> {
    let (kb, cb) = (k_dim / bk, c_dim / bc);
    assert_eq!(wb.len(), k_dim * c_dim * r_dim * s_dim);
    let mut out = vec![0.0; wb.len()];
    for ikb in 0..kb {
        for icb in 0..cb {
            for r in 0..r_dim {
                for s in 0..s_dim {
                    let blk = ((((ikb * cb) + icb) * r_dim + r) * s_dim + s) * bc * bk;
                    for ic in 0..bc {
                        for ik in 0..bk {
                            let dst = (((ikb * bk + ik) * c_dim + (icb * bc + ic)) * r_dim + r)
                                * s_dim
                                + s;
                            out[dst] = wb[blk + ic * bk + ik];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Packed conv weights → packed dual-conv weights for backward-by-data:
/// Wᵀ[Cb][Kb][R][S][bk][bc] with the spatial taps rotated 180°
/// (`(r, s) → (R-1-r, S-1-s)`), i.e. the weights of the "dual convolution"
/// of [27] that turns the bwd pass into a forward-shaped loop nest.
#[allow(clippy::too_many_arguments)]
pub fn dual_conv_weights(
    wb: &[f32],
    k_dim: usize,
    c_dim: usize,
    r_dim: usize,
    s_dim: usize,
    bk: usize,
    bc: usize,
) -> Vec<f32> {
    let (kb, cb) = (k_dim / bk, c_dim / bc);
    let mut out = vec![0.0; wb.len()];
    for ikb in 0..kb {
        for icb in 0..cb {
            for r in 0..r_dim {
                for s in 0..s_dim {
                    let src = ((((ikb * cb) + icb) * r_dim + r) * s_dim + s) * bc * bk;
                    let (rr, ss) = (r_dim - 1 - r, s_dim - 1 - s);
                    let dst = ((((icb * kb) + ikb) * r_dim + rr) * s_dim + ss) * bk * bc;
                    for ic in 0..bc {
                        for ik in 0..bk {
                            out[dst + ik * bc + ic] = wb[src + ic * bk + ik];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Activations I[N][C][H][W] → blocked, spatially padded
/// I[N][Cb][H+2ph][W+2pw][bc] with zero borders. The physical padding is
/// what lets every BRGEMM input block of the direct convolution be a plain
/// offset into the tensor, border pixels included.
#[allow(clippy::too_many_arguments)]
pub fn pack_conv_act(
    x: &[f32],
    n_dim: usize,
    c_dim: usize,
    h_dim: usize,
    w_dim: usize,
    bc: usize,
    ph: usize,
    pw: usize,
) -> Vec<f32> {
    let cb = c_dim / bc;
    let (hp, wp) = (h_dim + 2 * ph, w_dim + 2 * pw);
    let mut out = vec![0.0; n_dim * cb * hp * wp * bc];
    pack_conv_act_into(x, n_dim, c_dim, h_dim, w_dim, bc, ph, pw, &mut out);
    out
}

/// [`pack_conv_act`] into a caller-owned buffer (allocation-free variant;
/// `out` must have the padded blocked length and is fully overwritten,
/// zero borders included).
#[allow(clippy::too_many_arguments)]
pub fn pack_conv_act_into(
    x: &[f32],
    n_dim: usize,
    c_dim: usize,
    h_dim: usize,
    w_dim: usize,
    bc: usize,
    ph: usize,
    pw: usize,
    out: &mut [f32],
) {
    assert_eq!(c_dim % bc, 0);
    assert_eq!(x.len(), n_dim * c_dim * h_dim * w_dim);
    let cb = c_dim / bc;
    let (hp, wp) = (h_dim + 2 * ph, w_dim + 2 * pw);
    assert_eq!(out.len(), n_dim * cb * hp * wp * bc);
    // A reused buffer may hold stale borders; the pad region must be zero.
    out.fill(0.0);
    for n in 0..n_dim {
        for icb in 0..cb {
            for h in 0..h_dim {
                for w in 0..w_dim {
                    let dst = (((n * cb + icb) * hp + (h + ph)) * wp + (w + pw)) * bc;
                    for ic in 0..bc {
                        out[dst + ic] = x[((n * c_dim + (icb * bc + ic)) * h_dim + h) * w_dim + w];
                    }
                }
            }
        }
    }
}

/// Blocked (optionally padded) activations → plain NCHW.
#[allow(clippy::too_many_arguments)]
pub fn unpack_conv_act(
    xb: &[f32],
    n_dim: usize,
    c_dim: usize,
    h_dim: usize,
    w_dim: usize,
    bc: usize,
    ph: usize,
    pw: usize,
) -> Vec<f32> {
    let cb = c_dim / bc;
    let (hp, wp) = (h_dim + 2 * ph, w_dim + 2 * pw);
    assert_eq!(xb.len(), n_dim * cb * hp * wp * bc);
    let mut out = vec![0.0; n_dim * c_dim * h_dim * w_dim];
    for n in 0..n_dim {
        for icb in 0..cb {
            for h in 0..h_dim {
                for w in 0..w_dim {
                    let src = (((n * cb + icb) * hp + (h + ph)) * wp + (w + pw)) * bc;
                    for ic in 0..bc {
                        out[((n * c_dim + (icb * bc + ic)) * h_dim + h) * w_dim + w] = xb[src + ic];
                    }
                }
            }
        }
    }
    out
}

/// Re-pad an already-blocked activation tensor:
/// `[N][Cb][H][W][bc]` → `[N][Cb][H+2ph][W+2pw][bc]` with zero borders,
/// by direct row copies (no unpack/repack round trip). Used by the
/// backward-by-data "dual convolution" to pad dO by (R-1, S-1).
#[allow(clippy::too_many_arguments)]
pub fn repad_blocked(
    src: &[f32],
    n_dim: usize,
    cb: usize,
    h_dim: usize,
    w_dim: usize,
    bc: usize,
    ph: usize,
    pw: usize,
) -> Vec<f32> {
    let (hp, wp) = (h_dim + 2 * ph, w_dim + 2 * pw);
    let mut out = vec![0.0f32; n_dim * cb * hp * wp * bc];
    repad_blocked_into(src, n_dim, cb, h_dim, w_dim, bc, ph, pw, &mut out);
    out
}

/// [`repad_blocked`] into a caller-owned buffer (allocation-free variant;
/// `out` must have the padded length and is fully overwritten, zero
/// borders included).
#[allow(clippy::too_many_arguments)]
pub fn repad_blocked_into(
    src: &[f32],
    n_dim: usize,
    cb: usize,
    h_dim: usize,
    w_dim: usize,
    bc: usize,
    ph: usize,
    pw: usize,
    out: &mut [f32],
) {
    assert_eq!(src.len(), n_dim * cb * h_dim * w_dim * bc);
    let (hp, wp) = (h_dim + 2 * ph, w_dim + 2 * pw);
    assert_eq!(out.len(), n_dim * cb * hp * wp * bc);
    out.fill(0.0);
    let row = w_dim * bc;
    for n in 0..n_dim {
        for icb in 0..cb {
            for h in 0..h_dim {
                let s = ((n * cb + icb) * h_dim + h) * row;
                let d = (((n * cb + icb) * hp + (h + ph)) * wp + pw) * bc;
                out[d..d + row].copy_from_slice(&src[s..s + row]);
            }
        }
    }
}

/// Inverse of [`repad_blocked`]: strip a spatial border off a blocked
/// activation tensor, `[N][Cb][H+2ph][W+2pw][bc]` → `[N][Cb][H][W][bc]`
/// (`h_dim`/`w_dim` are the *unpadded* dims). The CNN training driver uses
/// it to turn a conv `backward_data` result — which has the padded input
/// geometry — into the producing layer's output-gradient buffer.
#[allow(clippy::too_many_arguments)]
pub fn crop_blocked(
    src: &[f32],
    n_dim: usize,
    cb: usize,
    h_dim: usize,
    w_dim: usize,
    bc: usize,
    ph: usize,
    pw: usize,
) -> Vec<f32> {
    let (hp, wp) = (h_dim + 2 * ph, w_dim + 2 * pw);
    assert_eq!(src.len(), n_dim * cb * hp * wp * bc);
    let mut out = vec![0.0f32; n_dim * cb * h_dim * w_dim * bc];
    let row = w_dim * bc;
    for n in 0..n_dim {
        for icb in 0..cb {
            for h in 0..h_dim {
                let s = (((n * cb + icb) * hp + (h + ph)) * wp + pw) * bc;
                let d = ((n * cb + icb) * h_dim + h) * row;
                out[d..d + row].copy_from_slice(&src[s..s + row]);
            }
        }
    }
    out
}

/// Per-row channel transpose of blocked activations:
/// I[N][Cb][H][W][bc] → IT[N][Cb][H][bc][W]. The weight-update pass reads
/// activations channel-major ("Aᵀ" operand); this is its reformat
/// (counted in the UPD pass's reformat time, cf. Table 1 bwd&upd row).
pub fn transpose_act_rows(
    xb: &[f32],
    n_dim: usize,
    cb: usize,
    h_dim: usize,
    w_dim: usize,
    bc: usize,
) -> Vec<f32> {
    assert_eq!(xb.len(), n_dim * cb * h_dim * w_dim * bc);
    let mut out = vec![0.0; xb.len()];
    for n in 0..n_dim {
        for icb in 0..cb {
            for h in 0..h_dim {
                let base = ((n * cb + icb) * h_dim + h) * w_dim * bc;
                for w in 0..w_dim {
                    for ic in 0..bc {
                        out[base + ic * w_dim + w] = xb[base + w * bc + ic];
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn weights_2d_round_trip() {
        let mut rng = Rng::new(1);
        let (k, c, bk, bc) = (8, 12, 4, 3);
        let w = rng.vec_f32(k * c, -1.0, 1.0);
        let packed = pack_weights_2d(&w, k, c, bk, bc);
        assert_eq!(unpack_weights_2d(&packed, k, c, bk, bc), w);
    }

    #[test]
    fn weights_2d_block_is_gemm_operand() {
        // Element W[k][c] must land at packed[kb][cb][c%bc][k%bk].
        let (k, c, bk, bc) = (4, 4, 2, 2);
        let w: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let p = pack_weights_2d(&w, k, c, bk, bc);
        // block (kb=1, cb=0), ic=1, ik=0 → W[k=2][c=1] = 2*4+1 = 9
        let cb_ct = c / bc;
        let blk = (1 * cb_ct + 0) * bc * bk;
        assert_eq!(p[blk + 1 * bk + 0], 9.0);
    }

    #[test]
    fn act_2d_round_trip() {
        let mut rng = Rng::new(2);
        let (n, c, bn, bc) = (6, 10, 3, 5);
        let x = rng.vec_f32(n * c, -1.0, 1.0);
        let packed = pack_act_2d(&x, n, c, bn, bc);
        assert_eq!(unpack_act_2d(&packed, n, c, bn, bc), x);
    }

    #[test]
    fn transpose_packed_is_transpose() {
        let mut rng = Rng::new(3);
        let (k, c, bk, bc) = (6, 8, 3, 4);
        let w = rng.vec_f32(k * c, -1.0, 1.0);
        let p = pack_weights_2d(&w, k, c, bk, bc);
        let pt = transpose_packed_2d(&p, k, c, bk, bc);
        // pt viewed as pack of Wᵀ[C][K] with roles swapped: unpack and check.
        let wt = unpack_weights_2d(&pt, c, k, bc, bk);
        for ik in 0..k {
            for ic in 0..c {
                assert_eq!(wt[ic * k + ik], w[ik * c + ic], "({},{})", ik, ic);
            }
        }
    }

    #[test]
    fn conv_weights_round_trip() {
        let mut rng = Rng::new(4);
        let (k, c, r, s, bk, bc) = (8, 6, 3, 3, 4, 3);
        let w = rng.vec_f32(k * c * r * s, -1.0, 1.0);
        let p = pack_conv_weights(&w, k, c, r, s, bk, bc);
        assert_eq!(unpack_conv_weights(&p, k, c, r, s, bk, bc), w);
    }

    #[test]
    fn dual_conv_weights_rotate_and_transpose() {
        let (k, c, r, s, bk, bc) = (2, 2, 3, 1, 1, 1);
        let mut w = vec![0.0; k * c * r * s];
        // W[k=1][c=0][r=2][s=0] = 5
        w[((1 * c + 0) * r + 2) * s + 0] = 5.0;
        let p = pack_conv_weights(&w, k, c, r, s, bk, bc);
        let d = dual_conv_weights(&p, k, c, r, s, bk, bc);
        // dual: [cb=0][kb=1][rr=0][ss=0] (bk=bc=1 so flat index)
        let kb_ct = k / bk;
        let idx = (((0 * kb_ct + 1) * r + 0) * s + 0) * bk * bc;
        assert_eq!(d[idx], 5.0);
    }

    #[test]
    fn conv_act_pad_round_trip() {
        let mut rng = Rng::new(5);
        let (n, c, h, w, bc, ph, pw) = (2, 4, 5, 7, 2, 1, 2);
        let x = rng.vec_f32(n * c * h * w, -1.0, 1.0);
        let p = pack_conv_act(&x, n, c, h, w, bc, ph, pw);
        assert_eq!(unpack_conv_act(&p, n, c, h, w, bc, ph, pw), x);
        // Borders must be zero.
        let cb = c / bc;
        let (hp, wp) = (h + 2 * ph, w + 2 * pw);
        for icb in 0..cb {
            for ww in 0..wp {
                for ic in 0..bc {
                    assert_eq!(p[(((0 * cb + icb) * hp + 0) * wp + ww) * bc + ic], 0.0);
                }
            }
        }
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        // The `_into` reformat variants are the serving scratch path:
        // a reused buffer full of stale garbage must come out exactly as
        // the allocating variant produces it — zero borders included.
        let mut rng = Rng::new(31);
        let (n, c, bn, bc) = (4, 10, 2, 5);
        let x = rng.vec_f32(n * c, -1.0, 1.0);
        let want = pack_act_2d(&x, n, c, bn, bc);
        let mut dirty = vec![f32::NAN; n * c];
        pack_act_2d_into(&x, n, c, bn, bc, &mut dirty);
        assert_eq!(dirty, want);
        let mut back = vec![f32::NAN; n * c];
        unpack_act_2d_into(&want, n, c, bn, bc, &mut back);
        assert_eq!(back, x);

        let (h, w, ph, pw) = (3, 4, 1, 2);
        let img = rng.vec_f32(n * c * h * w, -1.0, 1.0);
        let want = pack_conv_act(&img, n, c, h, w, bc, ph, pw);
        let mut dirty = vec![f32::NAN; want.len()];
        pack_conv_act_into(&img, n, c, h, w, bc, ph, pw, &mut dirty);
        assert_eq!(dirty, want, "stale border values must be zeroed");

        let cb = c / bc;
        let blocked = rng.vec_f32(n * cb * h * w * bc, -1.0, 1.0);
        let want = repad_blocked(&blocked, n, cb, h, w, bc, ph, pw);
        let mut dirty = vec![f32::NAN; want.len()];
        repad_blocked_into(&blocked, n, cb, h, w, bc, ph, pw, &mut dirty);
        assert_eq!(dirty, want, "stale border values must be zeroed");
    }

    #[test]
    fn crop_blocked_inverts_repad() {
        let mut rng = Rng::new(7);
        let (n, cb, h, w, bc) = (2, 3, 4, 5, 2);
        let x = rng.vec_f32(n * cb * h * w * bc, -1.0, 1.0);
        for (ph, pw) in [(0usize, 0usize), (1, 2), (2, 2)] {
            let padded = repad_blocked(&x, n, cb, h, w, bc, ph, pw);
            assert_eq!(crop_blocked(&padded, n, cb, h, w, bc, ph, pw), x, "pad {:?}", (ph, pw));
        }
        // And it extracts the interior of a padded pack: pack with padding,
        // crop, compare against the pad-free pack.
        let (c, plain_h, plain_w, pbc) = (4, 3, 3, 2);
        let plain = rng.vec_f32(n * c * plain_h * plain_w, -1.0, 1.0);
        let padded = pack_conv_act(&plain, n, c, plain_h, plain_w, pbc, 1, 1);
        let cropped = crop_blocked(&padded, n, c / pbc, plain_h, plain_w, pbc, 1, 1);
        assert_eq!(cropped, pack_conv_act(&plain, n, c, plain_h, plain_w, pbc, 0, 0));
    }

    #[test]
    fn transpose_act_rows_is_per_row_transpose() {
        let mut rng = Rng::new(6);
        let (n, cb, h, w, bc) = (1, 2, 3, 4, 3);
        let x = rng.vec_f32(n * cb * h * w * bc, -1.0, 1.0);
        let t = transpose_act_rows(&x, n, cb, h, w, bc);
        for icb in 0..cb {
            for hh in 0..h {
                let base = ((icb) * h + hh) * w * bc;
                for ww in 0..w {
                    for ic in 0..bc {
                        assert_eq!(t[base + ic * w + ww], x[base + ww * bc + ic]);
                    }
                }
            }
        }
    }

    #[test]
    fn property_layout_round_trips() {
        Prop::new("layout round trips").cases(40).run(|g| {
            let bk = g.usize(1..=4);
            let bc = g.usize(1..=4);
            let k = bk * g.usize(1..=4);
            let c = bc * g.usize(1..=4);
            let w = g.vec_f32(k * c, -1.0, 1.0);
            if unpack_weights_2d(&pack_weights_2d(&w, k, c, bk, bc), k, c, bk, bc) != w {
                return Err(format!("2d weights k{} c{} bk{} bc{}", k, c, bk, bc));
            }
            let (r, s) = (g.usize(1..=3), g.usize(1..=3));
            let wc = g.vec_f32(k * c * r * s, -1.0, 1.0);
            let p = pack_conv_weights(&wc, k, c, r, s, bk, bc);
            if unpack_conv_weights(&p, k, c, r, s, bk, bc) != wc {
                return Err("conv weights".into());
            }
            // dual of dual = original packed transposed layout round trip
            let d = dual_conv_weights(&p, k, c, r, s, bk, bc);
            let dd = dual_conv_weights(&d, c, k, r, s, bc, bk);
            if dd != p {
                return Err("dual∘dual ≠ id".into());
            }
            Ok(())
        });
    }
}
