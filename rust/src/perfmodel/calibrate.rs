//! Measured machine constants: one-time microprobes persisted to a
//! host-keyed calibration file, so every efficiency number the repo
//! reports — profiler efficiency-vs-roofline, bench-table `eff%`, and the
//! autotune cost model's roofline ranking — is computed against *this*
//! machine, not the nominal constants baked into
//! [`crate::perfmodel::host_platform`]'s fallback.
//!
//! Two probes, in the spirit of the classics:
//!
//! * **Peak GFLOPS** — [`crate::perfmodel::fma_roofline_probe`], the
//!   register-resident FMA chain already used for the live peak probe.
//! * **Stream GB/s** — [`stream_triad_probe`], a STREAM-style triad
//!   (`a[i] = b[i] + s·c[i]`) over arrays far larger than the LLC, so the
//!   measured rate is memory bandwidth, not cache bandwidth.
//!
//! Results persist like the autotune cache ([`crate::autotune::cache`]):
//! a versioned JSON file (`$BRGEMM_CALIBRATION` or `calibration.json`,
//! alongside `tuning_cache.json`), keyed by `hostname|isa` so a file
//! carried to a different machine is a clean miss rather than a wrong
//! constant. `BRGEMM_RECALIBRATE=1` forces a fresh probe (and rewrites
//! the entry); deleting the file does the same.

use crate::brgemm::Isa;
use crate::util::json::{obj, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;
use std::time::Instant;

/// Schema version; entries from other versions are ignored on load (same
/// policy as the tuning cache — a calibration is always regenerable).
pub const FORMAT_VERSION: usize = 1;

/// Measured constants for one host.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Calibration {
    /// Sustained single-core FMA peak, GFLOPS.
    pub peak_gflops: f64,
    /// Sustained single-core triad bandwidth, GB/s.
    pub stream_gbs: f64,
}

impl Calibration {
    pub fn to_json(&self) -> Json {
        obj([
            ("peak_gflops", self.peak_gflops.into()),
            ("stream_gbs", self.stream_gbs.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Calibration> {
        let num = |k: &str| j.get(k).and_then(Json::as_f64).filter(|x| x.is_finite() && *x > 0.0);
        Some(Calibration { peak_gflops: num("peak_gflops")?, stream_gbs: num("stream_gbs")? })
    }
}

/// `hostname|isa` — the file key. Hostname comes from
/// `/proc/sys/kernel/hostname` (no libc for `gethostname`); on non-Linux
/// hosts it degrades to a constant, which still keys correctly for a
/// single-machine workflow.
pub fn host_key() -> String {
    let host = std::fs::read_to_string("/proc/sys/kernel/hostname")
        .map(|s| s.trim().to_string())
        .ok()
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown-host".to_string());
    format!("{}|{}", host, Isa::detect().name())
}

/// `$BRGEMM_CALIBRATION` or `calibration.json` in the working dir —
/// deliberately alongside the autotune cache's default.
pub fn default_path() -> PathBuf {
    std::env::var("BRGEMM_CALIBRATION")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("calibration.json"))
}

/// Parse a calibration file into its entry map. `None` when the file is
/// missing, malformed, or written at a different schema version.
pub fn load_entries(path: &Path) -> Option<BTreeMap<String, Calibration>> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    if j.get("version").and_then(Json::as_usize) != Some(FORMAT_VERSION) {
        return None;
    }
    let entries = j.get("entries").and_then(Json::as_obj)?;
    let mut out = BTreeMap::new();
    for (k, v) in entries {
        out.insert(k.clone(), Calibration::from_json(v)?);
    }
    Some(out)
}

/// This host's entry in the file at `path`, if any.
pub fn lookup(path: &Path) -> Option<Calibration> {
    load_entries(path)?.get(&host_key()).copied()
}

/// Merge this host's entry into the file at `path` (temp file + rename,
/// same torn-write discipline as the tuning cache). Entries for other
/// hosts are preserved.
pub fn save(path: &Path, cal: Calibration) -> std::io::Result<()> {
    let mut entries = load_entries(path).unwrap_or_default();
    entries.insert(host_key(), cal);
    let jentries: BTreeMap<String, Json> =
        entries.iter().map(|(k, c)| (k.clone(), c.to_json())).collect();
    let doc = obj([("version", FORMAT_VERSION.into()), ("entries", Json::Obj(jentries))]);
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, doc.to_string_pretty())?;
    std::fs::rename(&tmp, path)
}

/// STREAM-style triad `a[i] = b[i] + s·c[i]` over 32 MiB arrays (≫ LLC
/// share), repeated for `seconds`; reports the best pass's GB/s counting
/// the classic 3 × 4 bytes per element (two loads + one store;
/// write-allocate traffic is deliberately not charged, per STREAM).
pub fn stream_triad_probe(seconds: f64) -> f64 {
    const N: usize = 8 << 20; // 8 Mi f32 per array = 32 MiB each
    let b = vec![1.5f32; N];
    let c = vec![0.5f32; N];
    let mut a = vec![0.0f32; N];
    let s = 3.0f32;
    // One untimed pass warms the pages (first touch faults the arrays in).
    triad_pass(&mut a, &b, &c, s);
    let mut best_secs = f64::INFINITY;
    let t0 = Instant::now();
    loop {
        let t = Instant::now();
        triad_pass(&mut a, &b, &c, s);
        best_secs = best_secs.min(t.elapsed().as_secs_f64());
        if t0.elapsed().as_secs_f64() > seconds {
            break;
        }
    }
    std::hint::black_box(&a);
    if best_secs > 0.0 {
        (3 * N * std::mem::size_of::<f32>()) as f64 / best_secs / 1e9
    } else {
        0.0
    }
}

#[inline(never)]
fn triad_pass(a: &mut [f32], b: &[f32], c: &[f32], s: f32) {
    for i in 0..a.len() {
        a[i] = b[i] + s * c[i];
    }
}

/// Run both microprobes (a few hundred ms total).
pub fn probe() -> Calibration {
    Calibration {
        peak_gflops: crate::perfmodel::fma_roofline_probe(0.3),
        stream_gbs: stream_triad_probe(0.2),
    }
}

/// The calibration consulted by [`crate::perfmodel::host_platform`]:
/// loaded from [`default_path`] once per process, `None` when no entry
/// exists for this host (nominal fallback applies). Never probes — probing
/// is an explicit act ([`ensure`]), so merely reporting efficiency can't
/// cost a surprise half-second.
pub fn cached() -> Option<Calibration> {
    *cell().get_or_init(|| lookup(&default_path()))
}

fn cell() -> &'static OnceLock<Option<Calibration>> {
    static CACHED: OnceLock<Option<Calibration>> = OnceLock::new();
    &CACHED
}

/// Load-or-probe: returns the persisted calibration for this host when
/// one exists (and `BRGEMM_RECALIBRATE` is not set), otherwise probes and
/// persists. The bool is `true` on a file hit — what `tune` prints and
/// CI asserts on a second invocation.
pub fn ensure() -> (Calibration, bool) {
    let path = default_path();
    let force = std::env::var("BRGEMM_RECALIBRATE").map(|v| v == "1").unwrap_or(false);
    if !force {
        if let Some(c) = lookup(&path) {
            let _ = cell().set(Some(c));
            return (c, true);
        }
    }
    let c = probe();
    if let Err(e) = save(&path, c) {
        crate::log_warn!("calibration not persisted to {}: {}", path.display(), e);
    }
    let _ = cell().set(Some(c));
    (c, false)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join("brgemm_dl_calibrate_test");
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn triad_probe_reports_plausible_bandwidth() {
        let gbs = stream_triad_probe(0.05);
        // From a throttled VM (~1 GB/s) to a big server core (~100 GB/s):
        // the point is positive and finite, not a particular magnitude.
        assert!(gbs > 0.05 && gbs < 1000.0, "triad {} GB/s", gbs);
    }

    #[test]
    fn calibration_round_trips_through_file() {
        let path = tmpdir().join("cal_roundtrip.json");
        std::fs::remove_file(&path).ok();
        assert!(lookup(&path).is_none(), "missing file is a clean miss");
        let cal = Calibration { peak_gflops: 123.4, stream_gbs: 17.8 };
        save(&path, cal).unwrap();
        assert_eq!(lookup(&path), Some(cal));
        // A second save for the same host overwrites, not duplicates.
        let cal2 = Calibration { peak_gflops: 200.0, stream_gbs: 20.0 };
        save(&path, cal2).unwrap();
        assert_eq!(load_entries(&path).unwrap().len(), 1);
        assert_eq!(lookup(&path), Some(cal2));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_preserves_other_hosts_entries() {
        let path = tmpdir().join("cal_multihost.json");
        let other = obj([
            ("version", FORMAT_VERSION.into()),
            (
                "entries",
                obj([(
                    "elsewhere|avx512",
                    obj([("peak_gflops", 999.0.into()), ("stream_gbs", 99.0.into())]),
                )]),
            ),
        ]);
        std::fs::write(&path, other.to_string_pretty()).unwrap();
        save(&path, Calibration { peak_gflops: 50.0, stream_gbs: 5.0 }).unwrap();
        let entries = load_entries(&path).unwrap();
        assert_eq!(entries.len(), 2, "foreign entry must survive a save");
        assert_eq!(entries["elsewhere|avx512"].peak_gflops, 999.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stale_or_malformed_files_are_clean_misses() {
        let path = tmpdir().join("cal_malformed.json");
        std::fs::write(&path, "not json").unwrap();
        assert!(lookup(&path).is_none());
        std::fs::write(&path, r#"{"version":99,"entries":{}}"#).unwrap();
        assert!(load_entries(&path).is_none(), "wrong schema version ignored");
        // Non-positive constants are rejected at entry level.
        let bad = format!(
            r#"{{"version":{},"entries":{{"{}":{{"peak_gflops":0.0,"stream_gbs":5.0}}}}}}"#,
            FORMAT_VERSION,
            host_key()
        );
        std::fs::write(&path, bad).unwrap();
        assert!(lookup(&path).is_none(), "zero peak must not calibrate anything");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn host_key_carries_hostname_and_isa() {
        let k = host_key();
        assert!(k.contains('|'), "{}", k);
        assert!(k.ends_with(Isa::detect().name()), "{}", k);
    }
}
