//! Performance model: peak-flops probe and efficiency accounting.
//!
//! The paper reports every result as a fraction of machine peak (3,050
//! GFLOPS for the 28-core SKX at 1.7 GHz AVX-512). On this host the peak
//! is *measured*, not assumed: [`fma_roofline_probe`] runs a pure
//! register-resident FMA chain through the same AVX-512 microkernel
//! discipline and reports the sustained single-core GFLOPS, which the
//! benches then use as the denominator for their efficiency columns.
//! [`SKX_PAPER`] carries the paper's numbers so tables can print
//! paper-vs-ours side by side.
//!
//! [`calibrate`] persists the probed constants (peak GFLOPS plus a
//! STREAM-triad bandwidth) to a host-keyed calibration file;
//! [`host_platform`] consults it so profiler efficiency, bench tables and
//! the autotune cost model all rank against *measured* constants when a
//! calibration exists, with the nominal bandwidth as a labeled fallback.

pub mod calibrate;

use std::time::Instant;

/// The paper's experimental platform (§4.1).
#[derive(Debug, Clone, Copy)]
pub struct PlatformModel {
    pub name: &'static str,
    pub peak_gflops_f32: f64,
    pub cores: usize,
    pub stream_gbs: f64,
}

/// Skylake-SP 8180, turbo off, AVX-512 @1.7 GHz — the paper's testbed.
pub const SKX_PAPER: PlatformModel =
    PlatformModel { name: "SKX-8180 (paper)", peak_gflops_f32: 3050.0, cores: 28, stream_gbs: 105.0 };

/// Cache hierarchy model used by the autotuner's analytic pruning
/// (working-set-vs-cache constraints). Sizes are per core for L1/L2 and a
/// conservative per-core share for the shared last level.
#[derive(Debug, Clone, Copy)]
pub struct CacheModel {
    pub l1_bytes: usize,
    pub l2_bytes: usize,
    pub l3_bytes: usize,
    pub line_bytes: usize,
}

impl CacheModel {
    /// SKX-class defaults (32 KiB L1D, 1 MiB L2, ~1.4 MiB/core L3 share) —
    /// deliberately conservative so the model prunes rather than overfits.
    pub fn host_default() -> CacheModel {
        CacheModel { l1_bytes: 32 << 10, l2_bytes: 1 << 20, l3_bytes: 1 << 21, line_bytes: 64 }
    }
}

/// Nominal per-core STREAM figure used when no calibration file exists
/// (the paper's 105 GB/s socket ≈ 3.75 GB/s/core is memory-parallelism
/// limited; one core alone sustains more — a conservative midpoint).
pub const NOMINAL_STREAM_GBS: f64 = 12.0;

/// Single-core platform model of *this* host. When a persisted calibration
/// exists for this host ([`calibrate::cached`]) both constants are
/// *measured* — the platform name says `calibrated`. Otherwise the peak is
/// probed live ([`host_peak_gflops`]) and the bandwidth falls back to
/// [`NOMINAL_STREAM_GBS`], with the name labeling the fallback so no
/// downstream table can pass a nominal number off as measured.
pub fn host_platform() -> PlatformModel {
    match calibrate::cached() {
        Some(c) => PlatformModel {
            name: "host (calibrated)",
            peak_gflops_f32: c.peak_gflops,
            cores: 1,
            stream_gbs: c.stream_gbs,
        },
        None => PlatformModel {
            name: "host (probed peak, nominal bandwidth)",
            peak_gflops_f32: host_peak_gflops(),
            cores: 1,
            stream_gbs: NOMINAL_STREAM_GBS,
        },
    }
}

/// The peak used for bench-table efficiency columns: the persisted
/// calibration when present, else the live probe. The label distinguishes
/// the two in rendered output.
pub fn calibrated_peak() -> (f64, &'static str) {
    match calibrate::cached() {
        Some(c) => (c.peak_gflops, "calibrated"),
        None => (host_peak_gflops(), "probed this run (no calibration file)"),
    }
}

/// Roofline execution-time estimate: a kernel doing `flops` flops over
/// `bytes` of memory traffic cannot run faster than either roof allows.
pub fn roofline_secs(flops: f64, bytes: f64, p: &PlatformModel) -> f64 {
    (flops / (p.peak_gflops_f32 * 1e9)).max(bytes / (p.stream_gbs * 1e9))
}

/// Measured peak of this host (cached after the first probe).
pub fn host_peak_gflops() -> f64 {
    use std::sync::OnceLock;
    static PEAK: OnceLock<f64> = OnceLock::new();
    *PEAK.get_or_init(|| fma_roofline_probe(0.3))
}

/// Sustained FMA GFLOPS of one core: a fully register-resident BRGEMM
/// inner loop (the microkernel's 6×4-vector tile shape) with no memory
/// traffic beyond L1. `seconds` is the probe budget.
pub fn fma_roofline_probe(seconds: f64) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx512f") {
            // SAFETY: feature checked above.
            return unsafe { probe_avx512(seconds) };
        }
    }
    probe_scalar(seconds)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn probe_avx512(seconds: f64) -> f64 {
    use std::arch::x86_64::*;
    // 24 independent accumulator chains (the microkernel's tile) + 2
    // multiplicands: enough ILP to saturate both FMA ports.
    let mut acc = [_mm512_set1_ps(0.0); 24];
    let a = _mm512_set1_ps(1.000000119);
    let b = _mm512_set1_ps(0.999999881);
    let mut total_fmas: u64 = 0;
    let t0 = Instant::now();
    loop {
        for _ in 0..4096 {
            for chain in &mut acc {
                *chain = _mm512_fmadd_ps(a, b, *chain);
            }
        }
        total_fmas += 4096 * 24;
        if t0.elapsed().as_secs_f64() > seconds {
            break;
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    // Keep the accumulators alive.
    let mut sink = 0.0f32;
    for chain in &acc {
        let mut lanes = [0.0f32; 16];
        _mm512_storeu_ps(lanes.as_mut_ptr(), *chain);
        sink += lanes[0];
    }
    std::hint::black_box(sink);
    // 16 lanes × 2 flops per FMA.
    total_fmas as f64 * 16.0 * 2.0 / secs / 1e9
}

fn probe_scalar(seconds: f64) -> f64 {
    let mut acc = [0.0f32; 16];
    let t0 = Instant::now();
    let mut total: u64 = 0;
    loop {
        for _ in 0..65536 {
            for a in &mut acc {
                *a = 1.000000119f32.mul_add(0.999999881, *a);
            }
        }
        total += 65536 * 16;
        if t0.elapsed().as_secs_f64() > seconds {
            break;
        }
    }
    std::hint::black_box(acc);
    total as f64 * 2.0 / t0.elapsed().as_secs_f64() / 1e9
}

/// Efficiency of a measured rate against a peak.
pub fn efficiency(gflops: f64, peak: f64) -> f64 {
    gflops / peak
}

/// Estimated VMEM footprint (bytes) of a Pallas BRGEMM block configuration
/// — the L1 structural metric recorded in DESIGN.md §Perf (interpret-mode
/// wall-clock is meaningless, so the TPU story is argued from footprint +
/// MXU occupancy instead).
pub fn pallas_vmem_footprint(bm: usize, bn: usize, k: usize, bytes_per_el: usize) -> usize {
    // A tile + B tile + C tile + f32 accumulator.
    bm * k * bytes_per_el + k * bn * bytes_per_el + bm * bn * bytes_per_el + bm * bn * 4
}

/// MXU utilisation estimate: fraction of the 128×128 systolic array busy
/// for a (bm × bn) output tile with K-depth `k`.
pub fn mxu_utilization(bm: usize, bn: usize, k: usize) -> f64 {
    let eff_m = (bm.min(128)) as f64 / 128.0;
    let eff_n = (bn.min(128)) as f64 / 128.0;
    let eff_k = (k.min(128)) as f64 / 128.0 / ((k as f64 / 128.0).ceil().max(1.0) / (k as f64 / 128.0).max(1.0));
    (eff_m * eff_n * eff_k).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_reports_plausible_peak() {
        let g = fma_roofline_probe(0.05);
        // Anything from 1 (scalar VM) to 400 (full AVX-512 dual-port) is
        // plausible; the point is it's positive and finite.
        assert!(g > 0.5 && g < 1000.0, "peak {}", g);
    }

    #[test]
    fn host_peak_is_cached() {
        let a = host_peak_gflops();
        let b = host_peak_gflops();
        assert_eq!(a, b);
    }

    #[test]
    fn efficiency_math() {
        assert!((efficiency(50.0, 100.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn host_platform_labels_its_constant_source() {
        // Whether or not a calibration file exists in the test cwd, the
        // model must carry positive constants and an honest label.
        let p = host_platform();
        assert!(p.peak_gflops_f32 > 0.0 && p.stream_gbs > 0.0);
        assert!(
            p.name == "host (calibrated)" || p.name == "host (probed peak, nominal bandwidth)",
            "unlabeled platform: {}",
            p.name
        );
        let (peak, label) = calibrated_peak();
        assert!(peak > 0.0 && !label.is_empty());
    }

    #[test]
    fn roofline_takes_the_binding_roof() {
        let p = PlatformModel { name: "t", peak_gflops_f32: 100.0, cores: 1, stream_gbs: 10.0 };
        // Compute-bound: 1e11 flops / 1e11 flops-per-sec = 1 s >> 1e9 B / 1e10 B/s.
        assert!((roofline_secs(1e11, 1e9, &p) - 1.0).abs() < 1e-9);
        // Memory-bound: 1e11 B / 1e10 B/s = 10 s >> 1 s of compute.
        assert!((roofline_secs(1e11, 1e11, &p) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn cache_model_is_ordered() {
        let c = CacheModel::host_default();
        assert!(c.l1_bytes < c.l2_bytes && c.l2_bytes <= c.l3_bytes);
        assert!(c.line_bytes.is_power_of_two());
    }

    #[test]
    fn vmem_footprint_counts_all_tiles() {
        // 128x128 f32 tiles with k=256: A 128*256*4 + B 256*128*4 + C
        // 128*128*4 + acc 128*128*4
        let b = pallas_vmem_footprint(128, 128, 256, 4);
        assert_eq!(b, 128 * 256 * 4 + 256 * 128 * 4 + 128 * 128 * 4 + 128 * 128 * 4);
    }

    #[test]
    fn mxu_full_tile_is_full_util() {
        assert!((mxu_utilization(128, 128, 128) - 1.0).abs() < 1e-9);
        assert!(mxu_utilization(8, 128, 128) < 0.1);
    }
}
