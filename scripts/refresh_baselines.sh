#!/usr/bin/env bash
# Refresh the committed BENCH_*.json perf baselines from real bench runs
# on the current host.
#
#   scripts/refresh_baselines.sh            serve_load only (fast)
#   FULL=1 scripts/refresh_baselines.sh     also fig10a/fig10b (slow)
#
# The committed baselines feed scripts/ci.sh's `perfcheck --baseline`
# check. Each file is a *history* document:
#
#   {
#     "note":    "<schema description>",
#     "history": [ { "host": ..., "rev": ..., "date": ..., <bench doc> },
#                  ...appended oldest-first... ]
#   }
#
# perfcheck compares against the NEWEST entry only; older entries remain
# as the host's perf trajectory (inspect them to see when a number moved
# and under which rev). This script APPENDS a provenance-stamped entry per
# run instead of overwriting, so history survives every refresh. Bench
# rows carry {median, <key>_mad, iters} noise accounting; perfcheck widens
# its allowance to max(tolerance, 3*MAD) where a mad sibling exists.
#
# Baselines are host-dependent: refresh on the machine CI actually runs
# on. Entries with "host": "seed" are conservative placeholders recorded
# without a build host.

set -euo pipefail
cd "$(dirname "$0")/.."

append_entry() {
    # Append a provenance-stamped history entry built from a fresh bench
    # result to the committed baseline (creating the history document if
    # the baseline is missing or still in the legacy flat shape).
    local src=$1 dst=$2
    if command -v python3 >/dev/null 2>&1; then
        python3 - "$src" "$dst" <<'EOF'
import datetime, json, os, platform, subprocess, sys
src, dst = sys.argv[1], sys.argv[2]
entry = json.load(open(src))
host = platform.node() or "unknown"
rev = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                     capture_output=True, text=True).stdout.strip() or "unknown"
date = datetime.date.today().isoformat()
entry = {"host": host, "rev": rev, "date": date, **entry}
doc = None
if os.path.exists(dst):
    try:
        doc = json.load(open(dst))
    except ValueError:
        doc = None
if not isinstance(doc, dict) or "history" not in doc:
    # Legacy flat baseline (or missing/corrupt): the old doc becomes the
    # first history entry so no provenance is lost.
    legacy = []
    if isinstance(doc, dict):
        doc.pop("note", None)
        legacy = [{"host": "legacy", "rev": "legacy", "date": date, **doc}]
    doc = {"note": "perf baseline history; see scripts/refresh_baselines.sh "
                   "for the schema (perfcheck compares the newest entry)",
           "history": legacy}
doc["history"].append(entry)
json.dump(doc, open(dst, "w"), indent=2)
print(f"appended entry {host} @ {rev} ({date}) to {dst} "
      f"({len(doc['history'])} entr{'y' if len(doc['history']) == 1 else 'ies'})")
EOF
    elif [ ! -e "$dst" ]; then
        # No python3: a plain copy still yields valid perfcheck input (a
        # flat document is its own newest entry), but never clobber an
        # existing history.
        cp "$src" "$dst"
        echo "created $dst from $src (no python3: flat document, no history)"
    else
        echo "WARNING: no python3 — cannot append to $dst history; skipped" >&2
    fi
}

echo "== cargo bench --bench serve_load =="
cargo bench --bench serve_load
append_entry bench_results/serve_load.json BENCH_serve_load.json

if [ "${FULL:-0}" = "1" ]; then
    for fig in fig10a fig10b; do
        echo "== cargo bench --bench $fig =="
        cargo bench --bench "$fig"
        append_entry "bench_results/$fig.json" "BENCH_$fig.json"
    done
else
    echo "(FULL=1 to also refresh fig10a/fig10b — they take much longer)"
fi

echo "== sanity: refreshed baselines compare clean against themselves =="
./target/release/brgemm-dl perfcheck --baseline BENCH_serve_load.json \
    --current bench_results/serve_load.json --tolerance 0.1
echo "baselines refreshed"
