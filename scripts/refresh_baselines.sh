#!/usr/bin/env bash
# Refresh the committed BENCH_*.json perf baselines from real bench runs
# on the current host.
#
#   scripts/refresh_baselines.sh            serve_load only (fast)
#   FULL=1 scripts/refresh_baselines.sh     also fig10a/fig10b (slow)
#
# The committed baselines feed scripts/ci.sh's advisory `perfcheck
# --baseline` check. They are host-dependent, so refresh them on the
# machine CI actually runs on; each refreshed file records that host's
# measured numbers plus a provenance note. Placeholder baselines (the
# seed-time conservative guesses) should be replaced by a real run from
# this script as soon as a build host is available.

set -euo pipefail
cd "$(dirname "$0")/.."

stamp_note() {
    # Prepend a provenance note to a fresh bench result and write it over
    # the committed baseline. Uses python3 if available, else a plain copy
    # (the result is already valid perfcheck input either way).
    local src=$1 dst=$2
    if command -v python3 >/dev/null 2>&1; then
        python3 - "$src" "$dst" <<'EOF'
import json, platform, subprocess, sys
src, dst = sys.argv[1], sys.argv[2]
doc = json.load(open(src))
host = platform.node()
rev = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                     capture_output=True, text=True).stdout.strip() or "unknown"
doc = {"note": f"measured baseline from scripts/refresh_baselines.sh on "
               f"{host} @ {rev}; compared advisorily by scripts/ci.sh "
               f"(perfcheck --baseline)", **doc}
json.dump(doc, open(dst, "w"), indent=2)
print(f"refreshed {dst} from {src}")
EOF
    else
        cp "$src" "$dst"
        echo "refreshed $dst from $src (no python3: provenance note not stamped)"
    fi
}

echo "== cargo bench --bench serve_load =="
cargo bench --bench serve_load
stamp_note bench_results/serve_load.json BENCH_serve_load.json

if [ "${FULL:-0}" = "1" ]; then
    for fig in fig10a fig10b; do
        echo "== cargo bench --bench $fig =="
        cargo bench --bench "$fig"
        stamp_note "bench_results/$fig.json" "BENCH_$fig.json"
    done
else
    echo "(FULL=1 to also refresh fig10a/fig10b — they take much longer)"
fi

echo "== sanity: refreshed baselines compare clean against themselves =="
./target/release/brgemm-dl perfcheck --baseline BENCH_serve_load.json \
    --current bench_results/serve_load.json --tolerance 0.1
echo "baselines refreshed"
