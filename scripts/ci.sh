#!/usr/bin/env bash
# Tier-1 verification gate (referenced from ROADMAP.md).
#
#   scripts/ci.sh            build + test + (advisory) format check
#   CI_STRICT_FMT=1 scripts/ci.sh   make the format check a hard failure
#
# Build and tests are always hard gates. `cargo fmt --check` is advisory
# by default so a formatter version skew can never mask a real regression;
# set CI_STRICT_FMT=1 once the toolchain is pinned.

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== serve smoke (tiny model, 300 requests) =="
# Exercise the serving subsystem end to end: queue -> dynamic batcher ->
# worker pool -> drained shutdown. Fails hard if any request is lost.
./target/release/brgemm-dl serve --model mlp --requests 300 --rate 50000 \
    --max-batch 8 --serve-workers 2 --seed 7

echo "== train -> checkpoint -> serve smoke =="
# The model-artifact pipeline end to end: train 2 epochs with per-epoch
# checkpointing, resume the artifact for a 3rd epoch, then serve the
# trained weights and replay the training distribution through the
# batcher — the run fails unless served responses classify it well above
# chance (10 classes), i.e. unless learned (not random) weights flowed
# train -> artifact -> serve.
rm -rf checkpoints
./target/release/brgemm-dl run --config examples/checkpoint.json
./target/release/brgemm-dl run --config examples/checkpoint.json \
    --epochs 3 --resume checkpoints/mlp.bin
./target/release/brgemm-dl serve --model-path checkpoints/mlp.bin \
    --min-accuracy 0.5 --requests 300 --rate 50000 --serve-workers 2

echo "== rnn train -> checkpoint -> resume -> serve smoke =="
# The sequence workload through the same pipeline: train the LSTM
# classifier 2 epochs with per-epoch checkpointing, resume the artifact
# for a 3rd epoch, then serve the trained weights and replay the training
# distribution — the run fails unless served responses classify well
# above chance (4 classes), i.e. unless learned recurrent weights flowed
# train -> artifact -> serve.
./target/release/brgemm-dl run --config examples/rnn.json
./target/release/brgemm-dl run --config examples/rnn.json \
    --epochs 3 --resume checkpoints/rnn.bin
./target/release/brgemm-dl serve --model-path checkpoints/rnn.bin \
    --min-accuracy 0.5 --requests 200 --rate 20000 --serve-workers 2

echo "== cargo fmt --check =="
if cargo fmt --check; then
    echo "formatting clean"
elif [ "${CI_STRICT_FMT:-0}" = "1" ]; then
    echo "formatting check failed (CI_STRICT_FMT=1)" >&2
    exit 1
else
    echo "formatting check failed (advisory; set CI_STRICT_FMT=1 to enforce)" >&2
fi

echo "== cargo clippy -q --release (advisory) =="
if cargo clippy -q --release; then
    echo "clippy clean"
else
    # Advisory like the fmt check: lint drift (or a missing clippy
    # component) must never mask a real build/test regression above.
    echo "clippy reported issues or is unavailable (advisory)" >&2
fi

echo "== tier-1 green =="
