#!/usr/bin/env bash
# Tier-1 verification gate (referenced from ROADMAP.md).
#
#   scripts/ci.sh            build + test + (advisory) format check
#   CI_STRICT_FMT=1 scripts/ci.sh   make the format check a hard failure
#
# Build and tests are always hard gates. `cargo fmt --check` is advisory
# by default so a formatter version skew can never mask a real regression;
# set CI_STRICT_FMT=1 once the toolchain is pinned.

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== serve smoke (tiny model, 300 requests, 50ms SLO) =="
# Exercise the serving subsystem end to end: queue -> dynamic batcher ->
# worker pool -> drained shutdown. Fails hard if any request is lost.
# --metrics-out exercises the telemetry path: the report JSON must parse,
# carry the queue-wait/compute stage split, and show nonzero BRGEMM calls
# from the bucket plans' profiler slots. The SLO flags stamp every request
# with a 50ms deadline: the report must carry nonzero attainment plus the
# queue-wait/compute/reload violation attribution counters.
./target/release/brgemm-dl serve --model mlp --requests 300 --rate 50000 \
    --max-batch 8 --serve-workers 2 --seed 7 \
    --slo-latency-ms 50 --slo-objective 0.99 \
    --metrics-out serve_metrics.json --metrics-every 0.5
test -f serve_metrics.json
./target/release/brgemm-dl perfcheck --metrics serve_metrics.json \
    --require queue_wait,compute,brgemm_calls,throughput_rps,slo_attainment,rss_peak_mb
for key in viol_queue_wait viol_compute viol_reload error_budget_remaining; do
    if ! grep -q "\"$key\"" serve_metrics.json; then
        echo "serve_metrics.json is missing SLO field '$key'" >&2
        exit 1
    fi
done
echo "SLO block present (attainment + violation attribution)"
# Resource plane: --metrics-out installs it, so the report must carry a
# resource block with RSS and CPU accounting. rss_peak_mb is required
# nonzero above; the CPU fields only need to be present (a sub-10ms-tick
# run can legitimately report 0.0 seconds).
for key in resource cpu_utime_s cpu_stime_s minor_faults alloc_count; do
    if ! grep -q "\"$key\"" serve_metrics.json; then
        echo "serve_metrics.json is missing resource field '$key'" >&2
        exit 1
    fi
done
echo "resource block present (rss_peak_mb nonzero + cpu/fault/alloc fields)"

echo "== train -> checkpoint -> serve smoke =="
# The model-artifact pipeline end to end: train 2 epochs with per-epoch
# checkpointing, resume the artifact for a 3rd epoch, then serve the
# trained weights and replay the training distribution through the
# batcher — the run fails unless served responses classify it well above
# chance (10 classes), i.e. unless learned (not random) weights flowed
# train -> artifact -> serve.
rm -rf checkpoints
# --metrics-out streams one JSON line per epoch (pass-timer breakdown)
# plus a final per-primitive BRGEMM profile; every line must parse and
# the profile must show nonzero brgemm_calls and a fwd timer.
./target/release/brgemm-dl run --config examples/checkpoint.json \
    --metrics-out train_metrics.jsonl
test -f train_metrics.jsonl
./target/release/brgemm-dl perfcheck --metrics train_metrics.jsonl \
    --require brgemm_calls,fwd,bwd,upd,final_accuracy,rss_peak_mb
# Every --metrics-out epoch line (and the final line) must carry the
# resource block.
if ! grep -q '"resource"' train_metrics.jsonl; then
    echo "train_metrics.jsonl is missing the resource block" >&2
    exit 1
fi
./target/release/brgemm-dl run --config examples/checkpoint.json \
    --epochs 3 --resume checkpoints/mlp.bin
./target/release/brgemm-dl serve --model-path checkpoints/mlp.bin \
    --min-accuracy 0.5 --requests 300 --rate 50000 --serve-workers 2

echo "== training trace smoke (data-parallel step spans + straggler index) =="
# A short 2-worker run with --trace-out must produce a Chrome trace-event
# document with nonzero complete spans covering several step stages
# (fwd/bwd/allreduce/update/...), i.e. the tracer actually followed the
# data-parallel step pipeline rather than logging one span kind in a loop.
# The same run's --metrics-out lines must carry the per-epoch straggler
# index (slowest-vs-mean replica compute, always >= 1 when present).
./target/release/brgemm-dl run --config examples/dist_mlp.json \
    --trace-out train_trace.json --metrics-out dist_metrics.jsonl
test -f train_trace.json
./target/release/brgemm-dl perfcheck --trace train_trace.json --min-span-cats 4
./target/release/brgemm-dl perfcheck --metrics dist_metrics.jsonl \
    --require straggler_index,allreduce_share

echo "== admin socket round trip (wait-ready -> stats -> reload -> metrics -> drain) =="
# A long-budget server run with --admin-sock, driven entirely from the
# admin client. --admin-sock installs the health plane, so the walk is
# observable end to end: --wait-ready blocks until the watchdog reports
# ready, live stats must parse, a reload pushed through the socket must
# show up in the next stats snapshot, `metrics` must render as Prometheus
# text, and a concurrent health poll must catch the draining state while
# the drain is in flight before the run exits cleanly.
sock="$(mktemp -u /tmp/brgemm_admin_XXXXXX.sock)"
./target/release/brgemm-dl serve --model mlp --requests 200000 --rate 2000 \
    --serve-workers 2 --seed 7 \
    --slo-latency-ms 50 --slo-objective 0.99 --admin-sock "$sock" &
serve_pid=$!
for _ in $(seq 1 100); do [ -S "$sock" ] && break; sleep 0.1; done
if [ ! -S "$sock" ]; then
    echo "admin socket $sock never appeared" >&2
    kill "$serve_pid" 2>/dev/null || true
    exit 1
fi
./target/release/brgemm-dl admin --sock "$sock" --wait-ready --timeout 10
if ! ./target/release/brgemm-dl admin --sock "$sock" --cmd health \
        | grep -q '"state":"ready"'; then
    echo "admin health did not report ready" >&2
    kill "$serve_pid" 2>/dev/null || true
    exit 1
fi
./target/release/brgemm-dl admin --sock "$sock" --cmd stats
./target/release/brgemm-dl admin --sock "$sock" \
    --cmd '{"cmd":"reload","path":"checkpoints/mlp.bin"}'
if ! ./target/release/brgemm-dl admin --sock "$sock" --cmd stats \
        | grep -q '"reloads":1'; then
    echo "socket reload not visible in admin stats" >&2
    kill "$serve_pid" 2>/dev/null || true
    exit 1
fi
# Prometheus exposition: non-empty # TYPE headers and the queue-depth
# gauge must both render from the live server.
./target/release/brgemm-dl admin --sock "$sock" --cmd metrics > admin_metrics.prom
if ! grep -q '^# TYPE ' admin_metrics.prom \
        || ! grep -q '^brgemm_serve_queue_depth ' admin_metrics.prom; then
    echo "admin metrics is not valid Prometheus text" >&2
    kill "$serve_pid" 2>/dev/null || true
    exit 1
fi
# Drain in the background and race health polls against it: the
# thread-per-connection admin server must answer them mid-drain, and at
# least one poll must observe the draining state.
./target/release/brgemm-dl admin --sock "$sock" --cmd drain &
drain_pid=$!
saw_draining=0
for _ in $(seq 1 60); do
    if ./target/release/brgemm-dl admin --sock "$sock" --cmd health 2>/dev/null \
            | grep -q '"state":"draining"'; then
        saw_draining=1
        break
    fi
    sleep 0.05
done
wait "$drain_pid"
wait "$serve_pid"
if [ "$saw_draining" != 1 ]; then
    echo "health never reported draining during the drain" >&2
    exit 1
fi
echo "admin round trip ok (ready -> reload visible -> metrics -> draining observed)"

echo "== rnn train -> checkpoint -> resume -> serve smoke =="
# The sequence workload through the same pipeline: train the LSTM
# classifier 2 epochs with per-epoch checkpointing, resume the artifact
# for a 3rd epoch, then serve the trained weights and replay the training
# distribution — the run fails unless served responses classify well
# above chance (4 classes), i.e. unless learned recurrent weights flowed
# train -> artifact -> serve.
./target/release/brgemm-dl run --config examples/rnn.json
./target/release/brgemm-dl run --config examples/rnn.json \
    --epochs 3 --resume checkpoints/rnn.bin
./target/release/brgemm-dl serve --model-path checkpoints/rnn.bin \
    --min-accuracy 0.5 --requests 200 --rate 20000 --serve-workers 2

echo "== mixed-length bucketed serving smoke (stacked rnn) =="
# Variable-length requests through the stacked (layers=2) artifact:
# lengths drawn from the GNMT-style distribution route through the
# length-bucket ladder, and the report's length-bucket split must show
# at least two distinct buckets actually served traffic.
./target/release/brgemm-dl serve --model-path checkpoints/rnn.bin \
    --seq-len-typical 4 --requests 300 --rate 50000 --serve-workers 2 \
    --slo-latency-ms 100 \
    --metrics-out serve_rnn_metrics.json --trace-out serve_rnn_trace.json
test -f serve_rnn_metrics.json
# slo_attainment here proves the per-length-bucket SLO split under real
# mixed-length load (the fixed-length smoke above covers batch buckets).
./target/release/brgemm-dl perfcheck --metrics serve_rnn_metrics.json \
    --require len_buckets,throughput_rps,slo_attainment
# The same run's --trace-out must hold request-, batch- and layer-level
# spans (>=3 categories): the serve pipeline traced end to end.
test -f serve_rnn_trace.json
./target/release/brgemm-dl perfcheck --trace serve_rnn_trace.json --min-span-cats 3
lb=$(grep -o '"len_bucket"' serve_rnn_metrics.json | wc -l)
if [ "$lb" -lt 2 ]; then
    echo "expected >=2 length buckets in serve_rnn_metrics.json, got $lb" >&2
    exit 1
fi
echo "length-bucket split covers $lb buckets"

echo "== calibration persistence (tune probes once, then loads the file) =="
# The first tune must probe the machine constants and persist them; the
# second must hit the persisted file instead of re-probing. Isolated
# cache + calibration paths so the check is hermetic.
cal_file="$(mktemp -u /tmp/brgemm_cal_XXXXXX.json)"
tune_cache="$(mktemp -u /tmp/brgemm_tune_XXXXXX.json)"
rm -f "$cal_file"
if ! BRGEMM_CALIBRATION="$cal_file" ./target/release/brgemm-dl tune \
        --primitive fc --n 32 --c 64 --k 64 --cache "$tune_cache" \
        | grep -q '^calibration: probed and saved'; then
    echo "first tune did not probe+persist calibration" >&2
    exit 1
fi
test -f "$cal_file"
if ! BRGEMM_CALIBRATION="$cal_file" ./target/release/brgemm-dl tune \
        --primitive fc --n 32 --c 64 --k 64 --cache "$tune_cache" \
        | grep -q '^calibration: loaded from'; then
    echo "second tune re-probed instead of loading $cal_file" >&2
    exit 1
fi
rm -f "$cal_file" "$tune_cache"
echo "calibration probed once, then served from the persisted file"

echo "== BENCH baseline self-validation (hard gate) =="
# Every committed baseline must parse and self-compare clean through
# perfcheck's history-aware, MAD-aware gate — an identical run never
# regresses. A baseline that fails here is corrupt and would silently
# disable the advisory perf check below.
for f in BENCH_*.json; do
    if ! ./target/release/brgemm-dl perfcheck --baseline "$f" --current "$f" \
            --tolerance 0.1; then
        echo "committed baseline $f fails perfcheck self-comparison" >&2
        exit 1
    fi
done
echo "all committed baselines parse and self-compare clean"

echo "== bench perf-regression check (advisory) =="
# Compare a fresh smoke-scale serve_load run against the committed
# baseline (BENCH_serve_load.json). Advisory only: the baselines are
# host-dependent, so a slow CI box must never mask a real build/test
# regression above. fig10a/fig10b are only compared when a previous
# full bench run left results behind (they are too slow to run here).
if cargo bench --bench serve_load -- --quick >/dev/null 2>&1; then
    ./target/release/brgemm-dl perfcheck --baseline BENCH_serve_load.json \
        --current bench_results/serve_load.json --tolerance 0.6 \
        || echo "serve_load perf below baseline (advisory)" >&2
else
    echo "serve_load bench failed to run (advisory)" >&2
fi
for fig in fig10a fig10b; do
    if [ -f "bench_results/$fig.json" ]; then
        ./target/release/brgemm-dl perfcheck --baseline "BENCH_$fig.json" \
            --current "bench_results/$fig.json" --tolerance 0.6 \
            || echo "$fig perf below baseline (advisory)" >&2
    fi
done

echo "== cargo fmt --check =="
if cargo fmt --check; then
    echo "formatting clean"
elif [ "${CI_STRICT_FMT:-0}" = "1" ]; then
    echo "formatting check failed (CI_STRICT_FMT=1)" >&2
    exit 1
else
    echo "formatting check failed (advisory; set CI_STRICT_FMT=1 to enforce)" >&2
fi

echo "== cargo clippy -q --release (advisory) =="
if cargo clippy -q --release; then
    echo "clippy clean"
else
    # Advisory like the fmt check: lint drift (or a missing clippy
    # component) must never mask a real build/test regression above.
    echo "clippy reported issues or is unavailable (advisory)" >&2
fi

echo "== tier-1 green =="
